"""End-to-end serving driver (the paper's use case is inference).

    PYTHONPATH=src:. python examples/serve_hdp.py

Trains (or loads the cached) small in-framework LM, then serves it with
**batched requests + continuous batching**, HDP active in prefill and
decode. Prints an A/B against dense attention: throughput, achieved
block/head sparsity, the FUM KV-bytes saving that sparsity implies on
TPU, and generated-token agreement.
"""
import argparse

import numpy as np

from benchmarks import common
from repro.attention import AttnSpec
from repro.serving import Engine, Request
from repro.serving.kv_cache import kv_read_bytes_per_step

ap = argparse.ArgumentParser()
ap.add_argument("--scale", default="tiny", choices=["tiny", "base"])
ap.add_argument("--requests", type=int, default=10)
ap.add_argument("--max-new", type=int, default=8)
ap.add_argument("--rho-b", type=float, default=-0.5)
args = ap.parse_args()

cfg, params = common.train_model(args.scale, steps=300)
from repro.core.config import HDPConfig  # noqa: E402

# calib="none": the paged serving backend quantizes its scout copy at
# cache-write time, so the static grid is the regime it operates in
hdp = HDPConfig(rho_b=args.rho_b, block_q=2, block_k=2, causal=True,
                head_pruning=True, tau_h=0.0, normalize_head_score=True,
                calib="none")

rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(6, 40)))
           .tolist() for _ in range(args.requests)]


def serve(with_hdp: bool, layout: str = "paged"):
    c = cfg.replace(hdp=hdp) if with_hdp else cfg
    eng = Engine(c, params=params, max_batch=4, max_len=96,
                 prefill_buckets=(16, 32, 64), collect_stats=with_hdp,
                 attn=AttnSpec(layout=layout))
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=args.max_new))
    res = eng.run()
    return res, eng.summary()


res_hdp, s_hdp = serve(True)                      # paged + HDP (FUM gather)
res_dense, s_dense = serve(False, "dense")        # dense slots, no pruning

agree = np.mean([
    np.mean(np.asarray(res_hdp[u].tokens) == np.asarray(res_dense[u].tokens))
    for u in res_hdp])
dense_b, hdp_b = kv_read_bytes_per_step(
    cfg, 32768, 1, s_hdp["block_sparsity"])

print(f"\nserving bench-{args.scale} (trained in-framework), "
      f"{args.requests} requests x {args.max_new} new tokens")
print(f"  HDP  : {s_hdp.get('decode_tok_s', 0):7.1f} tok/s   "
      f"block sparsity {s_hdp['block_sparsity']:.2f}  "
      f"head sparsity {s_hdp['head_sparsity']:.2f}  "
      f"page sparsity {s_hdp['page_sparsity']:.2f}")
print(f"  dense: {s_dense.get('decode_tok_s', 0):7.1f} tok/s")
print(f"  KV cache resident: paged {s_hdp['cache_bytes'] / 1e3:.1f} KB "
      f"(page size {s_hdp['page_size']}) vs dense slots "
      f"{s_dense['cache_bytes'] / 1e3:.1f} KB")
print(f"  generated-token agreement HDP vs dense: {agree:.3f}")
print(f"  FUM KV-read saving at this sparsity (32k ctx, per seq/step): "
      f"{dense_b / 1e6:.1f} MB -> {hdp_b / 1e6:.1f} MB "
      f"({1 - hdp_b / max(dense_b, 1):.0%} less HBM traffic on TPU)")
