"""Train a ~100M-param LM for a few hundred steps (end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

Uses the production launcher code path (sharded step builder, grad
accumulation, deterministic data, checkpointing, straggler log) on CPU.
`--small` (default in CI) trains a down-scaled model so the example
finishes in minutes; drop it to train the full ~100M config.
"""
import argparse
import sys

from repro.launch import train as train_launcher

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--small", action="store_true", default=True)
ap.add_argument("--full", dest="small", action="store_false",
                help="~100M params (slow on CPU)")
ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# qwen2-1.5b reduced is the small config; the full ~100M variant scales
# width/depth up but stays CPU-feasible for a few hundred steps.
argv = ["--arch", "qwen2-1.5b", "--steps", str(args.steps),
        "--checkpoint-dir", args.checkpoint_dir, "--log-every", "10"]
if args.small:
    argv += ["--reduced"]
else:
    argv += ["--seq-len", "512", "--global-batch", "8"]

sys.exit(train_launcher.main(argv))
