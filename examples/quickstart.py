"""Quickstart: HDP attention as a drop-in JAX module.

    PYTHONPATH=src python examples/quickstart.py

Shows the three public entry points at increasing integration depth:
 1. `core.hdp.hdp_attention`      — one attention call with HDP
 2. `ModelConfig(hdp=...)`         — any of the 10 architectures with HDP
 3. `kernels.ops.hdp_attention_tpu`— the Pallas TPU pipeline (interpret
    mode on CPU; the same call runs the real kernels on TPU).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig, PAPER_ASIC, TPU_KERNEL
from repro.core.hdp import dense_attention_reference, hdp_attention
from repro.kernels import ops
from repro.models import registry

# ---------------------------------------------------------------- 1. core
print("== 1. one attention call ==")
rng = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(rng, 3)
B, H, S, hd = 2, 4, 128, 64
q = jax.random.normal(kq, (B, H, S, hd)) * 1.5
k = jax.random.normal(kk, (B, H, S, hd)) * 1.5
v = jax.random.normal(kv, (B, H, S, hd))

# rho_b < 0 uses the min-branch of Alg. 2 line 15 (gentler pruning —
# random gaussian q/k have flat attention, so the mean-branch would prune
# hard; trained models tolerate far more, see examples/pruning_sweep.py)
cfg = PAPER_ASIC.replace(rho_b=-0.5, causal=True)     # 2x2 blocks, Alg. 2
out, stats = hdp_attention(q, k, v, cfg)
ref = dense_attention_reference(q, k, v, causal=True)
cos = float(jnp.vdot(out, ref) / (jnp.linalg.norm(out) * jnp.linalg.norm(ref)))
print(f"block sparsity {float(stats.block_sparsity):.2f}  "
      f"head sparsity {float(stats.head_sparsity):.2f}  "
      f"net {float(stats.net_sparsity):.2f}  cosine vs dense {cos:.4f}")

# -------------------------------------------------------------- 2. models
print("\n== 2. architecture with HDP (reduced qwen2 on CPU) ==")
mcfg = reduced(get_config("qwen2-1.5b"))
params, _ = registry.init_params(mcfg, jax.random.PRNGKey(1))
tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                            mcfg.vocab_size)
cache = registry.init_cache(mcfg, 2, max_len=96)
logits, cache, _ = registry.apply_prefill(mcfg, params, {"tokens": tokens},
                                          cache)
print(f"prefill logits {logits.shape}, cache leaves "
      f"{len(jax.tree.leaves(cache))}; hdp enabled: {mcfg.hdp.enabled}")
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
logits2, cache, _ = registry.apply_decode(mcfg, params, tok, cache,
                                          jnp.asarray(64))
print(f"decode step logits {logits2.shape}")

# ------------------------------------------------------------- 3. kernels
print("\n== 3. Pallas TPU pipeline (interpret mode on CPU) ==")
kcfg = TPU_KERNEL.replace(block_q=64, block_k=64, rho_b=0.4)
out_k, st = ops.hdp_attention_tpu(q, k, v, kcfg, return_stats=True)
ref_k, _ = hdp_attention(q, k, v, kcfg)
err = float(jnp.abs(out_k - ref_k).max())
print(f"kernel vs core-reference max err {err:.2e}  "
      f"block sparsity {float(st['block_sparsity']):.2f}  "
      f"kept blocks/row {float(st['kept_blocks_per_row']):.1f}")
print("\nquickstart OK")
