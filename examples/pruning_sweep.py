"""Sweep HDP's pruning knobs on a trained model and print the frontier.

    PYTHONPATH=src:. python examples/pruning_sweep.py

Trains (or loads the cached) small in-framework LM, then sweeps
(rho_B, tau_H) and prints net sparsity vs top-1 agreement — the Fig. 10
trade-off curve users tune in deployment. A compact version of
benchmarks/net_pruning.py intended as template code.
"""
import numpy as np

from benchmarks import common
from benchmarks.head_pruning import theta_head_samples
from repro.core.config import HDPConfig
from repro.core.hdp import hdp_attention

cfg, params = common.train_model("tiny", steps=300)
batches = common.eval_batches(1)

base = HDPConfig(block_q=2, block_k=2, approx=True, causal=True,
                 head_pruning=True, tau_h=-1.0)
th = theta_head_samples(cfg, params, batches,
                        base.replace(block_pruning=False))

print(f"{'rho_b':>6} {'tau_pct':>8} {'net_sparsity':>13} {'agreement':>10}")
for rho in (-0.5, 0.01, 0.3, 0.6):
    for pct in (0, 15):
        tau = float(np.percentile(th, pct)) if pct else -1.0
        hdp = base.replace(rho_b=rho, tau_h=tau)

        def attn(li, q, k, v, _hdp=hdp):
            return hdp_attention(q, k, v, _hdp)[0]

        ag = common.agreement_with(cfg, params, attn, batches)
        caps = common.capture_qkv(cfg, params, batches[0])
        nets = [float(hdp_attention(c["q"], c["k"], c["v"], hdp)[1]
                      .net_sparsity) for c in caps]
        print(f"{rho:6.2f} {pct:8d} {np.mean(nets):13.3f} {ag:10.3f}")
print("\npick the sparsest point that meets your fidelity budget.")
