"""Zero-copy decode hot path: donation, fused multi-step decode, FUM scan.

Load-bearing guarantees pinned here:

* horizon-H fused decode is token-for-token identical to H=1 across
  paged and dense layouts — including EOS firing mid-horizon and slots
  finishing while others continue;
* the decode step donates the serving cache: after one step the old page
  pool buffer is deleted (aliased in place, not copied), and a stale
  handle taken around a donating call cannot be reused
  (``DonatedCacheError``);
* the FUM contract survives donation and the chunked page scan: memory
  the page table never references (free pages) can be NaN-poisoned
  without changing a single generated token, and the >page_chunk scan
  path agrees with the one-shot gather while never touching pruned
  pages;
* ``Engine.run(max_steps)`` exhaustion warns (or raises on strict=True),
  marks the affected Results incomplete, and a follow-up run() finishes
  them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.models.attention import hdp_paged_decode_attention, scout_int8
from repro.serving import Engine, Request
from repro.serving.kv_cache import DonatedCacheError

F32 = jnp.float32


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _qwen(calib="none", enabled=True):
    cfg = reduced(get_config("qwen2-1.5b"))
    return cfg.replace(hdp=cfg.hdp.replace(enabled=enabled, calib=calib))


def _serve(cfg, params, prompts, horizon, *, max_new=5, stagger=True, **kw):
    eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                 prefill_buckets=(16, 32), decode_horizon=horizon, **kw)
    for uid, p in enumerate(prompts):
        mn = max_new + (uid % 3 if stagger else 0)
        eng.submit(Request(uid, p, max_new_tokens=mn))
    res = eng.run()
    return eng, {u: r.tokens for u, r in res.items()}


# ------------------------------------------------------ fused loop identity
@pytest.mark.parametrize("layout", [
    "paged",
    pytest.param("dense", marks=pytest.mark.slow),
])
def test_horizon_matches_single_step(layout):
    """Staggered budgets force slots to finish mid-horizon while their
    batch neighbors keep decoding — output must not notice."""
    cfg = _qwen()
    kw = {"attn": AttnSpec(layout=layout)}
    prompts = _prompts(4, seed=3)
    eng, h1 = _serve(cfg, None, prompts, 1, **kw)
    for horizon in (3, 4, 8):
        _, hH = _serve(cfg, eng.params, prompts, horizon, **kw)
        assert hH == h1, f"{layout} horizon={horizon}: {hH} != {h1}"


def test_eos_mid_horizon_matches_single_step():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=1, max_len=64, decode_horizon=1)
    eng.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8))
    ref = eng.run()[0].tokens
    j = next((i for i in range(1, len(ref)) if ref[i] not in ref[:i]), None)
    if j is None:
        pytest.skip("degenerate generation: all tokens identical")
    outs = {}
    for horizon in (1, 4, 8):
        e2 = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                    decode_horizon=horizon)
        e2.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8,
                          eos_id=ref[j]))
        outs[horizon] = e2.run()[0].tokens
    assert all(o == ref[:j + 1] for o in outs.values()), outs


def test_decode_horizon_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_DECODE_HORIZON", "3")
    assert Engine(_qwen(), max_batch=1, max_len=32).horizon == 3
    # explicit kwarg wins over the env
    assert Engine(_qwen(), max_batch=1, max_len=32,
                  decode_horizon=1).horizon == 1
    with pytest.raises(ValueError):
        Engine(_qwen(), max_batch=1, max_len=32, decode_horizon=0)


# ----------------------------------------------------------------- donation
def test_decode_step_donates_cache():
    """The decode jit aliases the page pool in place: after one step the
    pre-step pool buffer is deleted — no second copy of the pool exists."""
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=64, decode_horizon=4)
    for uid, p in enumerate(_prompts(2, seed=5)):
        eng.submit(Request(uid, p, max_new_tokens=4))
    eng._admit()
    old = eng.pages.cache
    eng.step()
    assert all(old[k].is_deleted() for k in old), \
        "donation rejected: decode step allocated a second page pool"
    eng.run()

    dense = Engine(cfg, params=eng.params, max_batch=2, max_len=64,
                   attn=AttnSpec(layout="dense"))
    dense.submit(Request(0, _prompts(1, seed=5)[0], max_new_tokens=4))
    dense._admit()
    old_k = dense.slots.cache["k"]
    dense.step()
    assert old_k.is_deleted()
    dense.run()


def test_decode_failure_restores_cache_handle():
    """A decode-trace failure must not strand the engine: the donated
    handle is restored so the real error surfaces and the engine stays
    usable, not a later DonatedCacheError."""
    from repro.attention import BackendUnsupported
    cfg = _qwen()
    eng = Engine(cfg, max_batch=1, max_len=32,
                 attn=AttnSpec(decode="pallas_flash", allow_fallback=False))
    eng.submit(Request(0, [1, 2, 3], max_new_tokens=2))
    with pytest.raises(BackendUnsupported):
        eng.step()
    _ = eng.pages.cache               # handle restored


def test_stale_cache_handle_guard():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=1, max_len=32)
    cache = eng.pages.take()
    with pytest.raises(DonatedCacheError):
        _ = eng.pages.cache
    eng.pages.put(cache)
    with pytest.raises(DonatedCacheError):
        eng.pages.put(cache)          # put without a prior take
    _ = eng.pages.cache               # restored handle is live again


def test_poisoned_free_pages_never_read_with_donation():
    """NaN-poisoning pool memory the page tables never reference cannot
    change a single token: decode reads only table-mapped pages (pruned
    ones scratch-redirected), through the donated in-place pool."""
    cfg = _qwen()
    prompts = _prompts(2, seed=7)

    eng, clean = _serve(cfg, None, prompts, 4, stagger=False)

    eng2 = Engine(cfg, params=eng.params, max_batch=2, max_len=64,
                  prefill_buckets=(16, 32), decode_horizon=4)
    for uid, p in enumerate(prompts):
        eng2.submit(Request(uid, p, max_new_tokens=5))
    eng2.step()                        # admit + first horizon
    free = list(eng2.pages._free)
    assert free, "test needs unallocated pages"
    c = eng2.pages.cache
    idx = jnp.asarray(free)
    if c["k_pages"].dtype == jnp.int8:
        # quantized pool: poison through both sentinel channels — the
        # -128 code (position-granular) AND NaN page scales (K and V
        # alike; these pages are never gathered, so even the V-poison
        # the live paths must avoid is safe here)
        from repro.core.quant import POISON_CODE
        vp = c["v_pages"]
        v_bad = (vp.at[:, idx].set(POISON_CODE) if vp.dtype == jnp.int8
                 else vp.at[:, idx].set(jnp.nan))
        eng2.pages.cache = {
            **c,
            "k_pages": c["k_pages"].at[:, idx].set(POISON_CODE),
            "v_pages": v_bad,
            "k_scale": c["k_scale"].at[:, idx].set(jnp.nan),
            "v_scale": c["v_scale"].at[:, idx].set(jnp.nan),
        }
    else:
        eng2.pages.cache = {
            **c,
            "k_pages": c["k_pages"].at[:, idx].set(jnp.nan),
            "v_pages": c["v_pages"].at[:, idx].set(jnp.nan),
        }
    res = eng2.run()
    poisoned = {u: r.tokens for u, r in res.items()}
    assert poisoned == clean, "NaN leaked from never-referenced pool pages"


# ------------------------------------------------- gather-free XLA scan path
def _paged_inputs(seed, hdp, n_pages, B=2, N=2, G=2, hd=8):
    ps = hdp.block_k
    P = 1 + B * n_pages
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, N, G, 1, hd), F32)
    ks = jax.random.normal(jax.random.fold_in(rng, 1), (P, ps, N, hd), F32)
    vs = jax.random.normal(jax.random.fold_in(rng, 2), (P, ps, N, hd), F32)
    ik = scout_int8(ks, hdp)
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n_pages)
    pos = jnp.full((B, 1), n_pages * ps - 1, jnp.int32)
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(n_pages * ps)
    k_pos = jnp.where(ar[None] <= pos, ar, -1)[:, None, None, :]
    return q, ks, vs, ik, table, q_pos, k_pos


def test_paged_scan_matches_one_shot_gather():
    """Forcing the chunked online-softmax path (page_chunk < Sk) agrees
    with the one-shot gather to float tolerance."""
    hdp = HDPConfig(block_q=1, block_k=4, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    q, ks, vs, ik, table, q_pos, k_pos = _paged_inputs(0, hdp, n_pages=8)
    one, _ = hdp_paged_decode_attention(
        q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp)
    for chunk in (4, 8, 12):
        scan, _ = hdp_paged_decode_attention(
            q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp,
            page_chunk=chunk)
        np.testing.assert_allclose(np.asarray(scan), np.asarray(one),
                                   atol=2e-5, rtol=2e-5)


def test_paged_scan_never_reads_pruned_pages():
    """The NaN-poison FUM contract holds on the chunked scan path too."""
    from repro.core.hdp import decode_scout
    from repro.models.attention import _fixed_split, _mask_bias
    hdp = HDPConfig(block_q=1, block_k=4, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    q, ks, vs, ik, table, q_pos, k_pos = _paged_inputs(1, hdp, n_pages=8)
    out, _ = hdp_paged_decode_attention(
        q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp,
        page_chunk=8)

    B, nP = table.shape
    ik_full = ik[table].reshape(B, nP * hdp.block_k, 2, 8).astype(F32)
    _, iq, _ = _fixed_split(q, hdp)
    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik_full,
                       preferred_element_type=F32)
    valid = _mask_bias(q_pos, k_pos, hdp.causal, 0)
    keep, _, _, _, head_kept = decode_scout(s_int, valid, hdp)
    fetched = (keep & head_kept[..., None]).any(axis=(1, 2))
    pruned = np.asarray(jnp.where(fetched, 0, table)).ravel()
    pruned = pruned[pruned > 0]
    assert pruned.size > 0, "test needs some pruned pages; lower rho_b"

    poison = jnp.asarray(pruned)
    out_bad, _ = hdp_paged_decode_attention(
        q, ks.at[poison].set(jnp.nan), vs.at[poison].set(jnp.nan), ik,
        table, q_pos=q_pos, k_pos=k_pos, hdp=hdp, page_chunk=8)
    assert bool(jnp.isfinite(out_bad).all()), \
        "NaN leaked: the scan path read a pruned page"
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_bad))


# --------------------------------------------------------- run() exhaustion
def test_run_budget_exhaustion_warns_and_marks_incomplete():
    cfg = _qwen()
    # horizon-loop semantics under test: exactly one token per step (the
    # speculative round would commit several — pin it off for env legs)
    eng = Engine(cfg, max_batch=1, max_len=64, decode_horizon=1,
                 spec_decode=False)
    for uid, p in enumerate(_prompts(3, seed=11)):
        eng.submit(Request(uid, p, max_new_tokens=6))
    with pytest.warns(RuntimeWarning, match="step budget"):
        res = eng.run(max_steps=3)
    assert not res[0].complete and len(res[0].tokens) == 3
    assert not res[1].complete and res[1].tokens == []   # still queued
    # engine state was left intact: finishing the drain completes them
    res = eng.run()
    assert all(r.complete for r in res.values())
    assert all(len(r.tokens) == 6 for r in res.values())


def test_run_budget_exhaustion_strict_raises():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=1, max_len=64)
    eng.submit(Request(0, _prompts(1, seed=12)[0], max_new_tokens=6))
    with pytest.raises(RuntimeError, match="step budget"):
        eng.run(max_steps=1, strict=True)
    assert not eng._results[0].complete
