"""Fault tolerance: lifecycle hardening, chaos injection, failover.

The load-bearing invariant extends test_serving's byte-identity to the
failure domain: whatever the harness breaks — a replica, one request's
logits, the page pool, a whole engine step — every request the fault
did NOT target must finish with tokens byte-identical to a fault-free
run, every targeted request must come back as a typed non-"ok" Result
(never an exception out of the serving loop, never a hang), and the
page allocator must drain back to zero afterwards. The injection
harness is deterministic (`FaultPlan` pins each event to a step
number), so these are plain assertions, not flaky chaos.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.common.transient import TransientError, is_transient
from repro.configs import get_config
from repro.configs.base import reduced
from repro.serving import (Engine, PoolExhausted, QueueFull, Request,
                           ReplicaSet, SchedulerConfig)
from repro.serving.faults import (FaultInjector, FaultPlan, InjectedFault,
                                  coerce_injector)
from repro.training.fault import retry


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _dense(cfg):
    return cfg if cfg.hdp is None else cfg.replace(
        hdp=cfg.hdp.replace(enabled=False))


def _qwen():
    return _dense(reduced(get_config("qwen2-1.5b")))


def _solo_tokens(cfg, params, reqs, **engine_kw):
    """Reference stream: each request served alone on a fresh engine."""
    out = {}
    for r in reqs:
        solo = Engine(cfg, params=params, max_batch=1, max_len=64,
                      prefill_buckets=(16, 32), **engine_kw)
        solo.submit(Request(99, list(r.prompt),
                            max_new_tokens=r.max_new_tokens))
        out[r.uid] = solo.run()[99].tokens
    return out


# --------------------------------------------------------------- harness
def test_fault_plan_parse_roundtrip():
    spec = "slow@0:s=0.01;exhaust@2;nan@3:uid=7;error@4;kill@5:replica=1"
    plan = FaultPlan.parse(spec)
    assert len(plan) == 5
    assert plan.spec == spec                    # events sort by step
    assert FaultPlan.parse(plan.spec).spec == plan.spec
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("frobnicate@1")
    with pytest.raises(ValueError, match="uid"):
        FaultPlan.parse("nan@1")
    with pytest.raises(ValueError, match="replica"):
        FaultPlan.parse("kill@1")
    with pytest.raises(ValueError, match="not 'kind@step"):
        FaultPlan.parse("error")


def test_injector_fires_each_event_once():
    inj = FaultInjector("exhaust@2;nan@1:uid=5")
    assert not inj.pool_exhausted(0)
    assert not inj.pool_exhausted(1)
    assert inj.pool_exhausted(5)          # at-or-after the scheduled step
    assert not inj.pool_exhausted(5)      # consumed — fires exactly once
    assert inj.nan_uids(3, {4}) == []     # uid 5 not live: stays pending
    assert inj.nan_uids(3, {4, 5}) == [5]
    assert inj.nan_uids(3, {4, 5}) == []
    assert not inj.pending
    assert len(inj.fired) == 2
    with pytest.raises(InjectedFault):
        FaultInjector("error@0").step_error(0)


def test_coerce_injector_env_fallback(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    assert coerce_injector(None) is None
    assert coerce_injector("") is None
    monkeypatch.setenv("REPRO_FAULT_PLAN", "exhaust@1")
    inj = coerce_injector(None)
    assert inj is not None and inj.plan.spec == "exhaust@1"
    assert coerce_injector(None, env=False) is None
    assert coerce_injector(inj) is inj    # injectors pass through shared


# ------------------------------------------------------ transient taxonomy
def test_transient_taxonomy_and_retry():
    assert is_transient(TransientError("x"))
    assert is_transient(PoolExhausted("pool"))  # subclass opt-in
    assert is_transient(OSError("io"))
    assert is_transient(RuntimeError("collective timeout"))
    assert not is_transient(RuntimeError("shape mismatch"))
    assert not is_transient(InjectedFault("boom"))  # hard by design
    assert not is_transient(ValueError("bad"))

    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientError("try again")
        return "ok"

    assert retry(flaky, retries=3, backoff_s=0.0) == "ok"
    assert len(calls) == 3

    def hard():
        calls.append(1)
        raise RuntimeError("assertion failed in kernel")

    calls.clear()
    with pytest.raises(RuntimeError, match="assertion"):
        retry(hard, retries=3, backoff_s=0.0)
    assert len(calls) == 1                # fail-fast: no retry burned


# ----------------------------------------------------- lifecycle hardening
def test_cancel_queued_and_active():
    cfg = _qwen()
    prompts = _prompts(4, seed=21)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True)
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=8))
    eng.step()                     # activates uids 0 and 1
    assert eng.cancel(0)           # active mid-decode
    assert eng.cancel(3)           # still waiting in the scheduler
    assert not eng.cancel(17)      # unknown uid
    out = eng.run()
    for uid in (0, 3):
        assert out[uid].status == "cancelled" and not out[uid].complete
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=8)
                        for u in (1, 2)])
    for uid in (1, 2):             # batchmates unaffected, byte-identical
        assert out[uid].status == "ok"
        assert out[uid].tokens == ref[uid]
    assert eng.metrics["req_cancelled"] == 2
    eng.pages.allocator.assert_drained()


def test_deadline_and_queue_wait_expiry():
    cfg = _qwen()
    prompts = _prompts(3, seed=22)
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True)
    eng.submit(Request(0, prompts[0], max_new_tokens=8))
    # expires while decoding (deadline already in the past at step 1)
    eng.submit(Request(1, prompts[1], max_new_tokens=8), deadline_s=0.0)
    # expires while queued behind the single slot
    eng.submit(Request(2, prompts[2], max_new_tokens=8),
               max_queue_wait_s=0.0)
    out = eng.run()
    assert out[0].status == "ok" and out[0].complete
    assert out[1].status == "deadline" and not out[1].complete
    assert out[2].status == "deadline" and not out[2].complete
    assert eng.metrics["req_deadline"] == 2
    eng.pages.allocator.assert_drained()


def test_submit_backpressure_queue_full():
    cfg = _qwen()
    prompts = _prompts(4, seed=23)
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True,
                 sched=SchedulerConfig(max_queue_depth=2))
    for uid in range(2):
        eng.submit(Request(uid, prompts[uid], max_new_tokens=4))
    with pytest.raises(QueueFull, match="max_queue_depth=2"):
        eng.submit(Request(2, prompts[2], max_new_tokens=4))
    assert is_transient(QueueFull("typed backpressure is retryable"))
    assert eng.metrics["queue_rejected"] == 1
    out = eng.run()                         # rejected request left no trace
    assert sorted(out) == [0, 1] and all(out[u].complete for u in out)


# --------------------------------------------------------- injected faults
def test_injected_step_error_restores_donated_cache():
    cfg = _qwen()
    prompts = _prompts(3, seed=24)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True, faults="error@1")
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=6))
    with pytest.raises(InjectedFault):
        eng.run()
    # the crash fired AFTER take() donated the cache handle — the unwind
    # must have restored it, or every later step dies DonatedCacheError
    assert not eng.pages.donated
    out = eng.run()                # engine stays fully usable
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=6)
                        for u in range(3)])
    for uid in range(3):
        assert out[uid].complete and out[uid].tokens == ref[uid]
    eng.pages.allocator.assert_drained()


def test_injected_step_error_spec_decode():
    cfg = _qwen()
    prompts = _prompts(2, seed=25)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 spec_decode=True, draft_len=3, faults="error@1")
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=6))
    with pytest.raises(InjectedFault):
        eng.run()
    assert not eng.pages.donated
    out = eng.run()
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=6)
                        for u in range(2)])
    for uid in range(2):
        assert out[uid].complete and out[uid].tokens == ref[uid]
    eng.pages.allocator.assert_drained()


def test_injected_pool_exhaustion_defers_not_fails():
    cfg = _qwen()
    prompts = _prompts(4, seed=26)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True, faults="exhaust@0")
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=5))
    out = eng.run()                # stream scheduler defers and retries
    assert eng.metrics["faults_injected"] >= 1
    assert eng.metrics["sched_deferred"] >= 1
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=5)
                        for u in range(4)])
    for uid in range(4):
        assert out[uid].complete and out[uid].tokens == ref[uid]
    eng.pages.allocator.assert_drained()


def test_nan_tripwire_isolates_one_slot():
    cfg = _qwen()
    prompts = _prompts(3, seed=27)
    eng = Engine(cfg, max_batch=3, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True, decode_horizon=4, faults="nan@1:uid=1")
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=8))
    out = eng.run()
    assert out[1].status == "error" and not out[1].complete
    assert "non-finite" in out[1].error
    assert eng.metrics["req_errors"] == 1
    assert eng.metrics["faults_injected"] == 1
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=8)
                        for u in (0, 2)])
    for uid in (0, 2):             # batchmates keep token-identical streams
        assert out[uid].status == "ok" and out[uid].tokens == ref[uid]
    eng.pages.allocator.assert_drained()


def test_nan_tripwire_spec_decode():
    cfg = _qwen()
    prompts = _prompts(2, seed=28)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 spec_decode=True, draft_len=3, faults="nan@1:uid=0")
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=8))
    out = eng.run()
    assert out[0].status == "error" and not out[0].complete
    ref = _solo_tokens(cfg, params,
                       [Request(1, prompts[1], max_new_tokens=8)])
    assert out[1].status == "ok" and out[1].tokens == ref[1]
    # acceptance accounting must not go negative on the faulted round
    assert eng.metrics["accepted_tokens"] >= 0
    eng.pages.allocator.assert_drained()


# ------------------------------------------------------ preempt-and-restore
def test_preempt_and_restore_byte_identical():
    cfg = _qwen()
    prompts = _prompts(3, lo=12, hi=20, seed=29)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True,
                 sched=SchedulerConfig(preempt_after=2, watchdog_steps=60))
    params = eng.params
    # two long low-priority requests fill both slots...
    eng.submit(Request(0, prompts[0], max_new_tokens=24))
    eng.submit(Request(1, prompts[1], max_new_tokens=24))
    for _ in range(3):
        eng.step()
    # ...then a high-priority arrival must preempt one of them
    eng.submit(Request(2, prompts[2], max_new_tokens=4, priority=1))
    out = eng.run()
    assert eng.metrics["sched_preempted"] >= 1
    preempted = [u for u in out if out[u].preemptions >= 1]
    assert preempted
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u],
                                max_new_tokens=24 if u < 2 else 4)
                        for u in range(3)])
    for uid in range(3):           # including the preempted victim
        assert out[uid].complete and out[uid].tokens == ref[uid], f"req {uid}"
    eng.pages.allocator.assert_drained()


# ----------------------------------------------------------- replica failover
def test_replica_failover_exactly_once():
    cfg = _qwen()
    prompts = _prompts(4, seed=30)
    rs = ReplicaSet.build(cfg, 2, max_batch=2, max_len=64,
                          prefill_buckets=(16, 32), stream_sched=True,
                          faults="kill@1:replica=0")
    params = rs.engines[0].params
    for uid, p in enumerate(prompts):
        rs.submit(Request(uid, p, max_new_tokens=10))
    out = rs.run()
    s = rs.summary()
    assert s["health"] == ["dead", "up"]
    assert s["failovers"] == 1
    assert s["requests_failed_over"] >= 1
    assert s["faults_fired"] >= 1
    assert len(s["replica_queue_depth"]) == 2
    assert len(s["replica_inflight"]) == 2
    assert len(s["replica_last_step_s"]) == 2
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u], max_new_tokens=10)
                        for u in range(4)])
    for uid in range(4):           # moved requests resume byte-identically
        assert out[uid].complete and out[uid].tokens == ref[uid], f"req {uid}"
    assert sorted(out) == [0, 1, 2, 3]   # exactly once each, no dupes
    rs.engines[1].pages.allocator.assert_drained()  # survivor leaks nothing


def test_all_replicas_dead_raises():
    cfg = _qwen()
    rs = ReplicaSet.build(cfg, 1, max_batch=1, max_len=64,
                          prefill_buckets=(16, 32), stream_sched=True,
                          faults="kill@0:replica=0")
    rs.submit(Request(0, _prompts(1, seed=31)[0], max_new_tokens=4))
    with pytest.raises(RuntimeError, match="every replica is dead"):
        rs.run()


# -------------------------------------------------------- chaos acceptance
def test_chaos_identity_acceptance():
    """The PR's acceptance gate: one seeded plan combining a replica
    kill, a NaN-poisoned slot, an injected pool exhaustion and a
    priority preemption — every non-faulted request must land
    byte-identical to a fault-free run, the faulted one must return a
    typed error, and the surviving allocator must drain to zero."""
    cfg = _qwen()
    prompts = _prompts(7, lo=10, hi=20, seed=32)
    plan = "slow@0:s=0.005;exhaust@2;nan@1:uid=3;kill@3:replica=0"
    rs = ReplicaSet.build(
        cfg, 2, max_batch=2, max_len=64, prefill_buckets=(16, 32),
        stream_sched=True, faults=plan,
        sched=SchedulerConfig(preempt_after=2, watchdog_steps=80))
    params = rs.engines[0].params
    for uid in range(6):
        rs.submit(Request(uid, prompts[uid], max_new_tokens=12))
    # 5 pre-steps: replica 0 dies at fleet step 3 and fails its work over,
    # and by step 5 the survivor's slots are BOTH re-occupied by long
    # requests with more queued behind them — so the high-priority arrival
    # below cannot slide into a free slot and must preempt
    for _ in range(5):
        rs.step()
    rs.submit(Request(6, prompts[6], max_new_tokens=4, priority=1))
    out = rs.run(max_steps=400)

    s = rs.summary()
    assert s["failovers"] == 1 and s["health"].count("dead") == 1
    assert rs.faults is not None and not rs.faults.pending  # plan consumed
    total_preempted = sum(e.metrics["sched_preempted"] for e in rs.engines)
    assert total_preempted >= 1

    # the NaN-targeted request errors; everyone else is byte-identical
    assert out[3].status == "error" and not out[3].complete
    ref = _solo_tokens(cfg, params,
                       [Request(u, prompts[u],
                                max_new_tokens=12 if u < 6 else 4)
                        for u in range(7) if u != 3])
    for uid in ref:
        assert out[uid].status == "ok" and out[uid].complete, f"req {uid}"
        assert out[uid].tokens == ref[uid], f"req {uid}"
    # no request lost, none served twice
    assert sorted(out) == list(range(7))
    for i, eng in enumerate(rs.engines):   # survivors drain to zero
        if rs.health[i] == "up":
            eng.pages.allocator.assert_drained()
