"""The while-aware HLO cost parser, validated against ground truth.

The parser exists because ``cost_analysis()`` counts scan bodies once;
these tests prove the parser's totals equal (a) hand-computed flops and
(b) XLA's own cost_analysis on the *unrolled* program, and that SPMD
collective bytes match analytic expectations.
"""
from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestTripCounts:
    def test_scan_flops_equal_unrolled(self):
        def scanned(x, ws):
            def body(c, w):
                return c @ w, ()
            return jax.lax.scan(body, x, ws)[0]

        def unrolled(x, ws):
            def body(c, w):
                return c @ w, ()
            return jax.lax.scan(body, x, ws, unroll=10)[0]

        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
        ps = hlo_cost.module_cost(_compile(scanned, x, ws).as_text())
        cu = _compile(unrolled, x, ws)
        pu = hlo_cost.module_cost(cu.as_text())
        ca = cu.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        expect = 10 * 2 * 256 ** 3
        assert ps.flops == pytest.approx(expect, rel=0.01)
        assert pu.flops == pytest.approx(expect, rel=0.01)
        assert ps.flops == pytest.approx(float(ca["flops"]), rel=0.01)

    def test_nested_scan_multiplies(self):
        def nested(x, ws):
            def outer(c, _):
                def inner(ci, w):
                    return jnp.tanh(ci @ w), ()
                return jax.lax.scan(inner, c, ws)[0], ()
            return jax.lax.scan(outer, x, None, length=4)[0]

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        c = hlo_cost.module_cost(_compile(nested, x, ws).as_text())
        expect = 4 * 6 * 2 * 128 ** 3
        assert c.flops == pytest.approx(expect, rel=0.02)

    def test_scanned_weights_read_once_per_iter(self):
        """Bytes: the [L,...] weight stack streams once per scan, not L
        times (the dynamic-slice override)."""
        L, D = 8, 256

        def scanned(x, ws):
            def body(c, w):
                return c @ w, ()
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        c = hlo_cost.module_cost(_compile(scanned, x, ws).as_text())
        stack = L * D * D * 4
        slice_bytes = D * D * 4
        # the per-iteration weight slice is charged at slice volume
        # (read+write of the sliced copy = 2x per iter), NOT the full
        # stack: a broken override would charge ~stack per iteration.
        ds = sum(v for k, v in c.bytes_by_label.items()
                 if "dynamic_slice" in k)
        assert ds <= 2.5 * slice_bytes * L, (ds, c.bytes_by_label)
        # and total traffic (dot reads/writes + loop-carry copies) stays
        # below the stack-per-iteration blowup (~2x the correct total)
        assert c.bytes < stack * L


class TestCollectives:
    def test_spmd_allreduce_bytes(self):
        """Needs >1 host device -> separate process with XLA_FLAGS."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline import hlo_cost
mesh = jax.make_mesh((8,), ("model",))
x = jax.ShapeDtypeStruct((256, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))
w = jax.ShapeDtypeStruct((1024, 2048), jnp.float32,
                         sharding=NamedSharding(mesh, P("model", None)))
c = jax.jit(lambda a, b: a @ b,
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
pc = hlo_cost.module_cost(c.as_text())
assert abs(pc.flops - 2*256*1024*2048/8) / (2*256*1024*2048/8) < 0.01, pc.flops
assert pc.coll_by_kind.get("all-reduce", 0) == 256*2048*4, pc.coll_by_kind
print("OK")
"""
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True,
                             env={**__import__("os").environ,
                                  "PYTHONPATH": "src"},
                             cwd="/root/repo", timeout=300)
        assert "OK" in out.stdout, out.stderr[-2000:]


class TestAnalysis:
    def test_analyze_shape(self):
        def f(a, b):
            return jnp.tanh(a @ b)

        a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        compiled = _compile(f, a, a)
        r = analysis.analyze(compiled, model_flops_per_device=2 * 512 ** 3)
        assert r.bottleneck in ("compute", "memory", "collective")
        assert r.flops == pytest.approx(2 * 512 ** 3, rel=0.01)
        assert 0.9 < r.useful_ratio < 1.1
        assert r.top_flops and r.top_bytes
        d = r.as_dict()
        assert {"compute_t", "memory_t", "collective_t"} <= set(d)

    def test_model_flops_kinds(self):
        from repro.configs import SHAPES, get_config
        cfg = get_config("qwen2-1.5b")
        tr = analysis.model_flops(cfg, SHAPES["train_4k"], 256)
        pf = analysis.model_flops(cfg, SHAPES["prefill_32k"], 256)
        de = analysis.model_flops(cfg, SHAPES["decode_32k"], 256)
        assert tr > pf > de > 0
