"""Quantized KV pool invariants (the int8-first paged serving store).

Load-bearing guarantees pinned here:

* the shared per-page grid (core.quant) round-trips within half a grid
  step, saturates at the code range, never emits the reserved
  POISON_CODE, and is idempotent — dequantized values re-encode to the
  same codes and are fixed points of ``quantize_fixed`` (the property
  that lets every consumer downstream of a dequant share the fp32
  pipeline's maths verbatim);
* poison survives quantization through BOTH channels: the -128 sentinel
  decodes to NaN position-granularly, a NaN page scale poisons the
  whole page, and the finite scout views ignore either channel;
* the FUM no-DMA contract holds on int8 pools in every paged stage-3
  backend (XLA gather slab, XLA online-softmax page-chunk scan, and the
  gather-free Pallas kernel): poisoning pruned pages cannot change the
  output, a NaN-scaled *visible* page trips NaN;
* the quantized pipeline is bit-identical to the fp32 pipeline fed the
  same round-tripped values (power-of-two scale: the dequant multiply
  is exact in fp32);
* COW keeps the donor page's codes AND scale byte-identical, and
  prefix-cache hits under ``kv_dtype="int8"`` are token-identical to
  cold serves (the prefill-time round-trip guarantee);
* the tuner's epoch token threads through the prefill AND chunked-
  prefill jits: one forced probe flip re-traces each exactly once;
* the serving summary reports the dtype-aware resident footprint
  (int8 <= 0.35x fp32 bytes per cached token).

Tests that pin int8-specific behavior set ``AttnSpec(kv_dtype=...)``
explicitly so the REPRO_KV_DTYPE CI legs cannot flip them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.core.hdp import decode_scout
from repro.core.quant import (POISON_CODE, decode_pool, encode_pool,
                              pool_scale, pool_view_finite, quantize_fixed)
from repro.models.attention import (_fixed_split, _mask_bias,
                                    hdp_paged_decode_attention, scout_int8)
from repro.serving import Engine, Request

F32 = jnp.float32
I8 = AttnSpec(kv_dtype="int8")


def _qwen(head_pruning=False):
    cfg = reduced(get_config("qwen2-1.5b"))
    return cfg.replace(hdp=cfg.hdp.replace(calib="none",
                                           head_pruning=head_pruning))


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


# ------------------------------------------------------------- grid unit
def test_roundtrip_bound_and_idempotence():
    rng = np.random.default_rng(0)
    for ib in (2, 4, 6):
        s0 = pool_scale(ib)
        lim = 127 * s0
        x = np.concatenate([
            rng.uniform(-lim, lim, size=2000),          # in-range
            rng.uniform(lim * 1.01, lim * 64, size=50),  # saturating
            -rng.uniform(lim * 1.01, lim * 64, size=50),
        ]).astype(np.float32)
        codes = np.asarray(encode_pool(jnp.asarray(x), ib))
        assert codes.min() >= -127, "encode emitted the POISON_CODE"
        dq = np.asarray(decode_pool(jnp.asarray(codes), s0))
        inr = np.abs(x) < lim + s0 / 2
        assert np.abs(dq - x)[inr].max() <= s0 / 2 * (1 + 1e-6)
        assert (np.sign(x[~inr]) * dq[~inr] == lim).all(), "no saturation"
        # idempotence: decoded values re-encode to the same codes and sit
        # exactly on the fixed-point grid the attention maths snaps K to
        assert np.array_equal(
            np.asarray(encode_pool(jnp.asarray(dq), ib)), codes)
        np.testing.assert_array_equal(
            np.asarray(quantize_fixed(jnp.asarray(dq), ib)), dq)


def test_roundtrip_error_bound_property():
    pytest.importorskip(
        "hypothesis", reason="property sweep needs hypothesis "
        "(requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=30, deadline=None)
    @given(hnp.arrays(np.float32, (3, 4, 2, 8),
                      elements=st.floats(-1000, 1000, width=32)),
           st.integers(min_value=2, max_value=6))
    def check(x, ib):
        s0 = pool_scale(ib)
        lim = 127 * s0
        codes = np.asarray(encode_pool(jnp.asarray(x), ib))
        assert codes.min() >= -127
        dq = np.asarray(decode_pool(jnp.asarray(codes), s0))
        inr = np.abs(x) < lim + s0 / 2
        if inr.any():
            assert np.abs(dq - x)[inr].max() <= s0 / 2 * (1 + 1e-6)
        if (~inr).any():
            assert (np.sign(x[~inr]) * dq[~inr] == lim).all()
        assert np.array_equal(
            np.asarray(encode_pool(jnp.asarray(dq), ib)), codes)

    check()


def test_poison_survives_quantization():
    ib = 4
    s0 = pool_scale(ib)
    codes = jnp.asarray([[5, POISON_CODE, -127]], jnp.int8)
    dq = np.asarray(decode_pool(codes, s0))
    assert dq[0, 0] == 5 * s0 and dq[0, 2] == -127 * s0
    assert np.isnan(dq[0, 1]), "sentinel code must decode to NaN"
    # the scout view ignores BOTH poison channels: sentinel -> 0 and the
    # (per-page NaN scale) channel does not enter the static-grid view
    view = np.asarray(pool_view_finite(codes, ib))
    assert np.isfinite(view).all() and view[0, 1] == 0.0
    # page-granular: a NaN scale poisons every dequant of the page
    assert np.isnan(np.asarray(decode_pool(codes, jnp.nan))).all()


# ------------------------------------------- FUM contract on int8 pools
@pytest.mark.parametrize("stage3,page_chunk", [
    ("xla", 128),          # gather-slab path
    ("xla", 8),            # online-softmax page-chunk scan (Sk=32 > 8)
    ("pallas_paged", 128),  # gather-free kernel (interpret mode on CPU)
])
def test_quantized_pools_match_fp32_and_never_dma_pruned(stage3, page_chunk):
    """int8 pools: bit-parity with the fp32 pipeline on round-tripped
    values; poisoned pruned pages cannot change the output; a NaN-scaled
    visible page trips NaN (the stage-3 tripwire)."""
    rng = jax.random.PRNGKey(0)
    B, N, G, hd, ps, nP = 2, 2, 2, 8, 4, 8
    P = 1 + B * nP
    Sk = nP * ps
    hdp = HDPConfig(block_q=1, block_k=ps, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    ib = hdp.int_bits
    ks = jax.random.normal(jax.random.fold_in(rng, 1), (P, ps, N, hd), F32)
    vs = jax.random.normal(jax.random.fold_in(rng, 2), (P, ps, N, hd), F32)
    kc, vc = encode_pool(ks, ib), encode_pool(vs, ib)
    kscl = jnp.full((P, N), pool_scale(ib), F32)
    vscl = jnp.full((P, N), pool_scale(ib), F32)
    q = jax.random.normal(jax.random.fold_in(rng, 3), (B, N, G, 1, hd), F32)
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, nP)
    pos = jnp.full((B, 1), Sk - 1, jnp.int32)      # every page visible
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(Sk)
    k_pos = jnp.where(ar[None] <= pos, ar, -1)[:, None, None, :]
    kw = dict(q_pos=q_pos, k_pos=k_pos, hdp=hdp, stage3=stage3,
              page_chunk=page_chunk)

    out_q, _ = hdp_paged_decode_attention(
        q, kc, vc, None, table, k_scale=kscl, v_scale=vscl, **kw)
    assert bool(jnp.isfinite(out_q).all())

    # bit-parity: the fp32 pipeline fed the decoded values (and the
    # write-time scout copy of them) must agree exactly — the
    # power-of-two scale makes every dequant multiply exact
    k_rt, v_rt = pool_view_finite(kc, ib), pool_view_finite(vc, ib)
    out_fp, _ = hdp_paged_decode_attention(
        q, k_rt, v_rt, scout_int8(k_rt, hdp), table, **kw)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_fp))

    # reconstruct the fetch decision exactly as stage 1 does
    ik = jnp.trunc(pool_view_finite(kc[table], ib)).reshape(B, Sk, N, hd)
    _, iq, _ = _fixed_split(q, hdp)
    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik,
                       preferred_element_type=F32)
    valid = _mask_bias(q_pos, k_pos, hdp.causal, 0)
    keep, _, _, _, head_kept = decode_scout(s_int, valid, hdp)
    fetched = (keep & head_kept[..., None]).any(axis=(1, 2))     # [B, nP]
    pruned = np.asarray(jnp.where(fetched, 0, table)).ravel()
    pruned = pruned[pruned > 0]
    assert pruned.size > 0, "test needs pruned pages; lower rho_b"

    # poison pruned pages through every stage-3 channel: V codes, and
    # both per-page scales. (K codes stay intact — they ARE the stage-1
    # scout stream, which always reads every allocated page by design;
    # the no-DMA contract is that stage 3 never dequantizes a pruned
    # page, so NaN scales and V poison must be invisible.)
    bad = jnp.asarray(pruned)
    out_bad, _ = hdp_paged_decode_attention(
        q, kc, vc.at[bad].set(POISON_CODE), None,
        table, k_scale=kscl.at[bad].set(jnp.nan),
        v_scale=vscl.at[bad].set(jnp.nan), **kw)
    assert bool(jnp.isfinite(out_bad).all()), \
        "poison leaked: a pruned page was gathered"
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_bad))

    # ... and a NaN scale on a FETCHED page must trip NaN: the scale
    # channel does not perturb the static-grid scout, so the fetch
    # decision is unchanged and stage 3 must hit the poisoned dequant
    vis = np.asarray(jnp.where(fetched, table, 0))[0]
    vis = vis[vis > 0][0]
    out_nan, _ = hdp_paged_decode_attention(
        q, kc, vc, None, table, k_scale=kscl.at[vis].set(jnp.nan),
        v_scale=vscl, **kw)
    assert bool(jnp.isnan(out_nan[0]).any()), \
        "NaN-scale poison on a visible page did not surface"


# ------------------------------------------------------ engine invariants
def test_cow_keeps_donor_codes_and_scales():
    """A full-prefix hit extends the shared tail: COW must leave the
    donor's cached page codes AND per-page scale byte-identical, and the
    extension must decode exactly like a cold serve."""
    cfg = _qwen()
    rng = np.random.default_rng(11)
    donor = rng.integers(1, 250, size=13).tolist()
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 prefix_cache=True, attn=I8)
    eng.submit(Request(0, donor, max_new_tokens=3))
    eng.run()
    matched = eng.prefix.match(donor[:12])
    tail = matched[-1]
    eng.pages.allocator.unref(matched)     # match refs for the caller
    before_k = np.asarray(eng.pages.cache["k_pages"][:, tail])
    before_s = np.asarray(eng.pages.cache["k_scale"][:, tail])
    assert before_k.dtype == np.int8

    eng.submit(Request(1, donor[:12], max_new_tokens=3))   # full hit
    res = eng.run()
    assert eng.summary()["cow_copies"] == 1
    np.testing.assert_array_equal(
        before_k, np.asarray(eng.pages.cache["k_pages"][:, tail]))
    np.testing.assert_array_equal(
        before_s, np.asarray(eng.pages.cache["k_scale"][:, tail]))

    solo = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                  prefill_buckets=(16, 32), prefix_cache=False, attn=I8)
    solo.submit(Request(9, donor[:12], max_new_tokens=3))
    assert res[1].tokens == solo.run()[9].tokens


def test_prefix_hit_token_identity_under_int8():
    """Hot (prefix-cache) and cold serves are token-identical on the
    int8 pool: prefill round-trips K/V through the pool grid before the
    write, so hits gather exactly what cold prefill would recompute."""
    cfg = _qwen()
    rng = np.random.default_rng(3)
    shared = rng.integers(1, 250, size=20).tolist()
    prompts = [shared + rng.integers(1, 250, size=5 + i).tolist()
               for i in range(3)] + [shared[:6], shared[:12]]

    def serve(params, prefix):
        eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                     prefill_buckets=(16, 32), prefix_cache=prefix, attn=I8)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=4))
        return eng, {u: r.tokens for u, r in eng.run().items()}

    e1, cold = serve(None, False)
    e2, hot = serve(e1.params, True)
    assert hot == cold, f"int8 hit tokens diverged: {hot} != {cold}"
    assert e2.summary()["prefix_hits"] > 0


def test_summary_reports_dtype_footprint():
    cfg = _qwen()
    legs = {}
    params = None
    for dt in ("int8", "fp8_v", "fp32"):
        eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                     prefill_buckets=(16, 32),
                     attn=AttnSpec(kv_dtype=dt))
        params = eng.params
        for uid, p in enumerate(_prompts(3, seed=5)):
            eng.submit(Request(uid, p, max_new_tokens=3))
        eng.run()
        legs[dt] = eng.summary()
        assert legs[dt]["kv_dtype"] == dt
        assert legs[dt]["cache_bytes_per_token"] > 0
    for dt in ("int8", "fp8_v"):
        ratio = legs[dt]["cache_bytes_per_token"] \
            / legs["fp32"]["cache_bytes_per_token"]
        assert ratio <= 0.35, \
            f"{dt} pool is x{ratio:.2f} of fp32 bytes/token (> 0.35)"


# --------------------------------------------------- epoch -> prefill jits
def test_probe_flip_retraces_prefill_jits_once(monkeypatch):
    """The tuner's epoch token is a static arg of the bucketed-prefill
    AND chunked-prefill jits: a forced probe flip re-traces each compiled
    entry exactly once (on the next admission after the flip), and the
    re-trace commits identical tokens."""
    monkeypatch.delenv("REPRO_ATTN_BACKEND", raising=False)
    cfg = _qwen()
    rng = np.random.default_rng(7)
    short = rng.integers(1, 250, size=6).tolist()
    long = rng.integers(1, 250, size=40).tolist()   # > largest bucket

    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(8, 16),
                 attn=AttnSpec(policy="cost"), prefix_cache=False,
                 spec_decode=False, stream_sched=False)

    def serve(uids):
        for uid, p in zip(uids, (short, long)):
            eng.submit(Request(uid, p, max_new_tokens=3))
        return {u: r.tokens for u, r in eng.run().items() if u in uids}

    ref = serve((0, 1))
    n_pref = eng._prefill_jit._cache_size()
    n_chunk = eng._chunk_jit._cache_size()
    assert n_pref > 0 and n_chunk > 0, "both prefill paths must have run"

    # identical re-serve, no flip: nothing recompiles
    out = serve((2, 3))
    assert out == {2: ref[0], 3: ref[1]}
    assert eng._prefill_jit._cache_size() == n_pref
    assert eng._chunk_jit._cache_size() == n_chunk

    # exactly one probe flip: the epoch bumps once during this wave's
    # decode, so the NEXT wave's prefills re-trace...
    flips = iter([True])
    eng.tuner.flush_probes = lambda: next(flips, False)
    out = serve((4, 5))
    assert out == {4: ref[0], 5: ref[1]}
    assert eng._attn_epoch == 1

    # ...exactly once per compiled entry, tokens unchanged
    out = serve((6, 7))
    assert out == {6: ref[0], 7: ref[1]}
    assert eng._prefill_jit._cache_size() == 2 * n_pref
    assert eng._chunk_jit._cache_size() == 2 * n_chunk


# --------------------------------------------------- absmax calibration
def test_absmax_roundtrip_beats_grid():
    """Per-page absmax calibration: on small-magnitude pages the static
    power-of-two grid wastes most of its code range; the calibrated
    scale round-trips within its own half step and far below the grid's
    error."""
    from repro.core.quant import absmax_page_scale, encode_pool_scaled

    rng = np.random.default_rng(17)
    ib = 4
    s0 = pool_scale(ib)
    x = jnp.asarray(rng.normal(0, 0.05, size=(6, 4, 2, 8))
                    .astype(np.float32))
    ks = absmax_page_scale(x, ib)                       # [P, N]
    assert ks.shape == (6, 2)
    codes = encode_pool_scaled(x, ks[:, None, :, None])
    assert int(codes.min()) >= -127, "calibrated encode emitted POISON_CODE"
    dq = np.asarray(codes, np.float32) * np.asarray(ks)[:, None, :, None]
    err_absmax = np.abs(dq - np.asarray(x)).max()
    assert err_absmax <= float(np.asarray(ks).max()) / 2 * (1 + 1e-6), \
        "calibrated round-trip exceeded its half-step bound"
    dq_grid = np.asarray(decode_pool(encode_pool(x, ib), s0))
    err_grid = np.abs(dq_grid - np.asarray(x)).max()
    assert err_absmax < err_grid / 4, \
        (f"absmax error {err_absmax:.5f} not clearly below the static "
         f"grid's {err_grid:.5f}")


def test_absmax_zero_page_falls_back_to_grid_scale():
    """An all-zero page has no absmax: the scale falls back to the
    static grid step (finite — a 0 scale would poison the dequant)."""
    from repro.core.quant import absmax_page_scale

    s = np.asarray(absmax_page_scale(jnp.zeros((2, 4, 2, 8), F32), 4))
    assert (s == pool_scale(4)).all()


def test_absmax_spec_and_defaults():
    """kv_scale is opt-in: defaults stay on the bit-parity grid, bad
    values and the fp32+absmax combination are rejected up front."""
    assert AttnSpec().kv_scale == "grid"
    assert AttnSpec(kv_dtype="int8").kv_scale == "grid"
    with pytest.raises(ValueError):
        AttnSpec(kv_scale="per_tensor")
    with pytest.raises(ValueError, match="absmax"):
        Engine(_qwen(), max_batch=1, max_len=32,
               attn=AttnSpec(kv_dtype="fp32", kv_scale="absmax"))


def test_absmax_decode_drift_gate_vs_fp32_oracle():
    """fp32 A/B drift gate: with pruning off (pure quantization A/B),
    the absmax pool's decode output drifts from the fp32 oracle by less
    than the static-grid pool does, and stays within a 10% bound of the
    oracle's output range."""
    from repro.core.quant import absmax_page_scale, encode_pool_scaled

    rng = jax.random.PRNGKey(5)
    B, N, G, hd, ps, nP = 2, 2, 2, 8, 4, 8
    P = 1 + B * nP
    Sk = nP * ps
    hdp = HDPConfig(block_q=1, block_k=ps, rho_b=0.99, causal=True,
                    head_pruning=False, calib="none")
    ib = hdp.int_bits
    # small-magnitude K/V: the regime where calibration matters
    ks = 0.05 * jax.random.normal(jax.random.fold_in(rng, 1),
                                  (P, ps, N, hd), F32)
    vs = 0.05 * jax.random.normal(jax.random.fold_in(rng, 2),
                                  (P, ps, N, hd), F32)
    q = jax.random.normal(jax.random.fold_in(rng, 3), (B, N, G, 1, hd), F32)
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, nP)
    pos = jnp.full((B, 1), Sk - 1, jnp.int32)
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(Sk)
    k_pos = jnp.where(ar[None] <= pos, ar, -1)[:, None, None, :]
    kw = dict(q_pos=q_pos, k_pos=k_pos, hdp=hdp, stage3="xla",
              page_chunk=128)

    out_fp, _ = hdp_paged_decode_attention(
        q, ks, vs, scout_int8(ks, hdp), table, **kw)

    ksc, vsc = absmax_page_scale(ks, ib), absmax_page_scale(vs, ib)
    out_a, _ = hdp_paged_decode_attention(
        q, encode_pool_scaled(ks, ksc[:, None, :, None]),
        encode_pool_scaled(vs, vsc[:, None, :, None]), None, table,
        k_scale=ksc, v_scale=vsc, kv_scale="absmax", **kw)

    g = jnp.full((P, N), pool_scale(ib), F32)
    out_g, _ = hdp_paged_decode_attention(
        q, encode_pool(ks, ib), encode_pool(vs, ib), None, table,
        k_scale=g, v_scale=g, **kw)

    ref = np.asarray(out_fp)
    assert np.isfinite(np.asarray(out_a)).all()
    drift_a = np.abs(np.asarray(out_a) - ref).max()
    drift_g = np.abs(np.asarray(out_g) - ref).max()
    assert drift_a < drift_g, \
        (f"absmax drift {drift_a:.5f} not below the static grid's "
         f"{drift_g:.5f} vs the fp32 oracle")
    assert drift_a <= 0.1 * np.abs(ref).max() + 1e-6, \
        f"absmax drift {drift_a:.5f} breaches the 10% oracle gate"


def test_absmax_serving_end_to_end():
    """The calibrated pool serves: full generations, deterministic under
    the FIXED format, kv_scale surfaced in the summary. (Byte-identity
    vs the grid pool is NOT asserted — absmax forfeits the write-time
    bit-parity by design; the drift gate above is its contract.)"""
    cfg = _qwen()
    prompts = _prompts(3, seed=19)
    a8 = AttnSpec(kv_dtype="int8", kv_scale="absmax")

    def serve(params):
        eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                     prefill_buckets=(16, 32), attn=a8)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=4))
        return eng, {u: r.tokens for u, r in eng.run().items()}

    eng, r1 = serve(None)
    assert all(len(t) == 4 for t in r1.values())
    assert eng.summary()["kv_scale"] == "absmax"
    assert eng.summary()["kv_dtype"] == "int8"
    _, r2 = serve(eng.params)
    assert r2 == r1, "absmax serving is not deterministic"


# ------------------------------------------------------------ kernel route
@pytest.mark.slow  # interpret-mode kernel per layer per step
def test_pallas_backend_matches_xla_under_int8():
    cfg = _qwen()
    prompts = _prompts(2, seed=11)
    eng, xla = None, None
    res = {}
    for backend in ("xla", "pallas"):
        e = Engine(cfg, params=eng.params if eng else None, max_batch=2,
                   max_len=64, prefill_buckets=(16, 32),
                   attn=AttnSpec(backend=backend, kv_dtype="int8"))
        eng = eng or e
        for uid, p in enumerate(prompts):
            e.submit(Request(uid, p, max_new_tokens=4))
        res[backend] = {u: r.tokens for u, r in e.run().items()}
    assert res["xla"] == res["pallas"]
