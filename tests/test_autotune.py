"""Cost-driven autotune subsystem: predictor, tuner, adaptive speculation.

Three contracts are load-bearing:

* the analytic predictor agrees with the while-aware HLO cost model on
  compiled attention jits — absolute FLOPs within a small factor, kv_len
  *scaling* tight (the ranking signal the tuner actually uses);
* cost-policy selection is deterministic and byte-identical to static
  selection end-to-end through the serving engine (off-TPU the cost
  model must rank the same winners the static priority order picks);
* acceptance-adaptive speculation stays byte-identical to greedy decode
  at ANY forced draft-length schedule, including k=1 (speculation off).
"""
from __future__ import annotations

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnCall, AttnSpec, DraftProfile, attention
from repro.attention.registry import (BACKEND_ENV, POLICY_ENV,
                                      effective_policy, resolve_backend)
from repro.autotune import (CallSig, SparsityEstimate, SpecConfig,
                            SpecController, Tuner, call_signature,
                            crossover_table, predict, predict_engine_step,
                            reset_default_tuner)
from repro.autotune.tuner import TUNER_CACHE_ENV, default_tuner
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.roofline import hlo_cost
from repro.roofline.hardware import (HOST_CPU, TPU_V5E, detect_profile,
                                     get_profile)
from repro.serving import Engine, Request

B, N, G, HD = 1, 2, 2, 8
HDP = HDPConfig(block_q=4, block_k=4, rho_b=0.5, tau_h=0.0,
                normalize_head_score=True, calib="max")


@pytest.fixture(autouse=True)
def _fresh_default_tuner():
    """Process-default tuner state must not leak between tests."""
    reset_default_tuner()
    yield
    reset_default_tuner()


def _decode_sig(kv=256, hdp=False, **kw):
    base = dict(mode="decode", layout="dense", batch=B, n_kv_heads=N,
                group=G, sq=1, hd=HD, kv_len=kv, hdp=hdp)
    if hdp:
        base.update(block_q=4, block_k=4)
    base.update(kw)
    return CallSig(**base)


# --------------------------------------------------------------- hardware
class TestHardware:
    def test_get_profile(self):
        assert get_profile("tpu_v5e") is TPU_V5E
        assert get_profile("host_cpu") is HOST_CPU
        with pytest.raises(KeyError):
            get_profile("h100")

    def test_detect_profile_matches_backend(self):
        prof = detect_profile()
        expect = TPU_V5E if jax.default_backend() == "tpu" else HOST_CPU
        assert prof is expect

    def test_analysis_reexports_tpu_constants(self):
        from repro.roofline import analysis
        assert analysis.PEAK_FLOPS == TPU_V5E.peak_flops
        assert analysis.HBM_BW == TPU_V5E.hbm_bw
        assert analysis.ICI_BW == TPU_V5E.ici_bw
        assert analysis.HBM_BYTES == TPU_V5E.mem_bytes

    def test_analyze_takes_profile(self):
        from repro.roofline import analysis
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        compiled = jax.jit(lambda a, b: a @ b).lower(x, x).compile()
        r_tpu = analysis.analyze(compiled)
        r_cpu = analysis.analyze(compiled, hw=HOST_CPU)
        assert r_tpu.hw == "tpu_v5e" and r_cpu.hw == "host_cpu"
        assert r_cpu.compute_t > r_tpu.compute_t  # slower envelope
        assert r_cpu.flops == r_tpu.flops         # counts are hw-free


# ---------------------------------------------------------------- CallSig
class TestCallSig:
    def test_dense_signature_from_live_shapes(self):
        call = AttnCall(mode="decode", layout="dense")
        q = jnp.zeros((B, N, G, 1, HD), jnp.float32)
        k = jnp.zeros((B, 32, N, HD), jnp.float32)
        sig = call_signature(call, q, k=k)
        assert (sig.batch, sig.n_kv_heads, sig.group) == (B, N, G)
        assert (sig.sq, sig.kv_len, sig.hd) == (1, 32, HD)
        assert sig.heads == N * G
        assert not sig.hdp and sig.page_size == 0

    def test_paged_signature_derives_extent_from_table(self):
        call = AttnCall(mode="decode", layout="paged", hdp=HDP,
                        per_slot=True)
        q = jnp.zeros((B, N, G, 1, HD), jnp.float32)
        cache = {"k_pages": jnp.zeros((9, 4, N, HD), jnp.float32)}
        table = jnp.ones((B, 6), jnp.int32)
        sig = call_signature(call, q, cache=cache, page_table=table)
        assert sig.kv_len == 6 * 4 and sig.page_size == 4
        assert sig.hdp and (sig.block_q, sig.block_k) == (4, 4)
        assert sig.per_slot

    def test_key_distinguishes_and_roundtrips(self):
        a, b = _decode_sig(kv=128), _decode_sig(kv=256)
        assert a.key() != b.key()
        assert a.key() == _decode_sig(kv=128).key()
        assert isinstance(hash(a), int)  # usable as a dict key directly


# -------------------------------------------------------------- predictor
class TestPredict:
    def test_monotonic_in_kv_len(self):
        ts = [predict("xla_dense", _decode_sig(kv=kv),
                      HOST_CPU).step_time(HOST_CPU)
              for kv in (128, 512, 2048)]
        assert ts[0] < ts[1] < ts[2]

    def test_dense_hdp_costs_more_than_dense(self):
        # dense-layout HDP streams every byte AND quantizes: pruning can
        # only win on the paged fetch-upon-mask path
        sig = _decode_sig(kv=1024, hdp=True)
        t_hdp = predict("xla_hdp", sig, TPU_V5E).step_time(TPU_V5E)
        t_dense = predict("xla_dense", _decode_sig(kv=1024),
                          TPU_V5E).step_time(TPU_V5E)
        assert t_hdp > t_dense

    def test_sparsity_shrinks_paged_hdp_bytes(self):
        sig = _decode_sig(kv=4096, hdp=True, layout="paged", page_size=16,
                          per_slot=True)
        lo = predict("paged_hdp_decode", sig, TPU_V5E,
                     SparsityEstimate(page=0.0))
        hi = predict("paged_hdp_decode", sig, TPU_V5E,
                     SparsityEstimate(page=0.9))
        assert hi.hbm_bytes < lo.hbm_bytes
        assert hi.step_time(TPU_V5E) < lo.step_time(TPU_V5E)

    def test_interpreted_pallas_never_wins_off_tpu(self):
        sig = _decode_sig(kv=4096)
        t_pallas = predict("pallas_flash", sig, HOST_CPU)
        t_dense = predict("xla_dense", sig, HOST_CPU)
        assert t_pallas.interpreted and not t_dense.interpreted
        assert t_pallas.step_time(HOST_CPU) > t_dense.step_time(HOST_CPU)
        # ...but natively compiled Pallas is competitive on TPU
        assert not predict("pallas_flash", sig, TPU_V5E).interpreted

    def test_prior_and_clamp(self):
        assert SparsityEstimate.prior(_decode_sig()) == SparsityEstimate()
        p = SparsityEstimate.prior(_decode_sig(hdp=True))
        assert p.block > 0 and p.page > 0
        c = SparsityEstimate(block=1.5, head=-0.3, page=0.5).clamped()
        assert c.block == 0.999 and c.head == 0.0 and c.page == 0.5

    def test_engine_step_dominated_by_weights(self):
        est = predict("xla_dense", _decode_sig(kv=256), TPU_V5E)
        t = predict_engine_step(1_000_000_000, 4, 24, est, TPU_V5E)
        assert t > 1_000_000_000 * 4 / TPU_V5E.hbm_bw  # weight-read floor
        assert t > 24 * est.step_time(TPU_V5E)


# ------------------------------------------------- predictor vs HLO cost
class TestHloAgreement:
    """The analytic model vs the compiled-program cost model.

    Absolute agreement is loose (XLA fuses, pads and re-materializes),
    but the kv_len *scaling* — the signal backend ranking rides on —
    must be tight.
    """

    SPEC = AttnSpec(backend="xla_dense", policy="static")

    def _compiled_cost(self, kv, sq=1):
        call = AttnCall(mode="decode" if sq == 1 else "prefill",
                        layout="dense")
        q = jnp.zeros((B, N, G, sq, HD), jnp.float32)
        k = jnp.zeros((B, kv, N, HD), jnp.float32)
        v = jnp.zeros((B, kv, N, HD), jnp.float32)
        fn = jax.jit(lambda q, k, v: attention(q, k, v, call,
                                               spec=self.SPEC)[0])
        compiled = fn.lower(q, k, v).compile()
        return hlo_cost.module_cost(compiled.as_text())

    def test_decode_flops_within_factor(self):
        for kv in (128, 512):
            hlo = self._compiled_cost(kv)
            est = predict("xla_dense", _decode_sig(kv=kv), HOST_CPU)
            assert est.flops / hlo.flops < 4.0, (kv, est.flops, hlo.flops)
            assert hlo.flops / est.flops < 4.0, (kv, est.flops, hlo.flops)
            assert est.hbm_bytes / hlo.bytes < 8.0
            assert hlo.bytes / est.hbm_bytes < 8.0

    def test_decode_kv_scaling_tight(self):
        hlo_ratio = self._compiled_cost(512).flops / \
            self._compiled_cost(128).flops
        pred_ratio = predict("xla_dense", _decode_sig(kv=512),
                             HOST_CPU).flops / \
            predict("xla_dense", _decode_sig(kv=128), HOST_CPU).flops
        assert 0.6 < hlo_ratio / pred_ratio < 1.6, (hlo_ratio, pred_ratio)

    def test_prefill_flops_within_factor(self):
        kv = 64
        hlo = self._compiled_cost(kv, sq=kv)
        sig = _decode_sig(kv=kv, mode="prefill", sq=kv)
        est = predict("xla_dense", sig, HOST_CPU)
        # predictor prices the causal triangle (kv/2); XLA computes the
        # full rectangle then masks — expect ~2x, gate at 4x
        assert est.flops / hlo.flops < 4.0
        assert hlo.flops / est.flops < 4.0


# -------------------------------------------------------------- crossover
class TestCrossover:
    SIG = CallSig(mode="decode", layout="paged", batch=4, n_kv_heads=2,
                  group=6, sq=1, hd=64, kv_len=0, page_size=16, hdp=True,
                  block_q=4, block_k=4, per_slot=True)

    def test_table_shape_and_fields(self):
        rows = crossover_table(self.SIG, TPU_V5E, (128, 8192), (0.0, 0.75))
        assert len(rows) == 4
        for r in rows:
            assert {"kv_len", "page_sparsity", "t_hdp_s", "t_dense_s",
                    "winner"} <= set(r)
            assert r["winner"] in ("hdp", "dense")

    def test_winner_flips_with_sparsity_times_kv(self):
        rows = crossover_table(self.SIG, TPU_V5E,
                               (128, 65536), (0.0, 0.9))
        by = {(r["kv_len"], r["page_sparsity"]): r["winner"] for r in rows}
        # short + dense-ish: the sparse pipeline's overhead loses
        assert by[(128, 0.0)] == "dense"
        # long + very sparse: fetch-upon-mask wins
        assert by[(65536, 0.9)] == "hdp"


# ------------------------------------------------------------------ tuner
def _cands(*names):
    return [types.SimpleNamespace(name=n) for n in names]


class TestTuner:
    CALL = AttnCall(mode="decode", layout="dense")

    def test_choose_picks_predicted_fastest(self):
        t = Tuner(hw=HOST_CPU)
        sig = _decode_sig(kv=512)
        best = t.choose(self.CALL, sig, _cands("xla_dense", "reference"))
        assert best.name == "xla_dense"  # oracle is priced out
        assert t.misses == 1 and t.hits == 0
        assert t.decision[sig.key()] == "xla_dense"
        assert not t.pending  # reference is nowhere near the margin

    def test_ambiguity_registers_pending_and_probe_flips(self):
        t = Tuner(hw=HOST_CPU, margin=1e9)  # everything is ambiguous
        sig = _decode_sig(kv=256)
        t.choose(self.CALL, sig, _cands("xla_dense", "reference"))
        assert sig.key() in t.pending
        t._probe = lambda call, sig, names: "reference"
        assert t.flush_probes() is True  # measured winner != prediction
        assert t.decision[sig.key()] == "reference"
        assert t.measured[sig.key()] == "reference"
        assert t.probes == 1 and not t.pending
        # next sighting is a measured-cache hit
        best = t.choose(self.CALL, sig, _cands("xla_dense", "reference"))
        assert best.name == "reference" and t.hits == 1

    def test_probe_failure_keeps_prediction(self):
        t = Tuner(hw=HOST_CPU, margin=1e9)
        sig = _decode_sig(kv=256)
        t.choose(self.CALL, sig, _cands("xla_dense", "reference"))

        def boom(call, sig, names):
            raise RuntimeError("probe exploded")

        t._probe = boom
        assert t.flush_probes() is False
        assert not t.pending  # never re-tried
        assert t.decision[sig.key()] == "xla_dense"
        assert t.flush_probes() is False  # idempotent when drained

    def test_real_probe_on_paged_hdp_call(self):
        # one end-to-end probe: synthetic inputs + jitted backend run
        call = AttnCall(mode="decode", layout="paged", hdp=HDP,
                        per_slot=True)
        sig = CallSig(mode="decode", layout="paged", batch=1, n_kv_heads=N,
                      group=G, sq=1, hd=HD, kv_len=8, page_size=4,
                      hdp=True, block_q=4, block_k=4, per_slot=True)
        t = Tuner(hw=HOST_CPU, probe_reps=1)
        assert t._probe(call, sig, ("paged_hdp_decode",)) \
            == "paged_hdp_decode"

    def test_save_load_roundtrip_warm_start(self, tmp_path):
        path = str(tmp_path / "tuner.json")
        t = Tuner(hw=HOST_CPU, margin=1e9)
        sig = _decode_sig(kv=256)
        t.choose(self.CALL, sig, _cands("xla_dense", "reference"))
        t._probe = lambda call, sig, names: "xla_dense"
        t.flush_probes()
        t.save(path)

        warm = Tuner(hw=HOST_CPU, cache_path=path)
        assert warm.measured == {sig.key(): "xla_dense"}
        warm.choose(self.CALL, sig, _cands("xla_dense", "reference"))
        assert warm.hits == 1 and warm.probes == 0 and not warm.pending

    def test_load_rejects_other_hardware(self, tmp_path):
        path = str(tmp_path / "tuner.json")
        t = Tuner(hw=HOST_CPU)
        t.measured["x"] = "xla_dense"
        t.save(path)
        other = Tuner(hw=TPU_V5E)
        assert other.load(path) is False and not other.measured

    def test_default_tuner_honors_cache_env(self, tmp_path, monkeypatch):
        path = str(tmp_path / "warm.json")
        src = Tuner()  # detected profile — what default_tuner will use
        src.measured["k"] = "xla_dense"
        src.save(path)
        monkeypatch.setenv(TUNER_CACHE_ENV, path)
        reset_default_tuner()
        assert default_tuner().measured == {"k": "xla_dense"}

    def test_decisions_deterministic_across_tuners(self):
        sigs = [_decode_sig(kv=kv, hdp=h)
                for kv in (64, 1024) for h in (False, True)]
        runs = []
        for _ in range(2):
            t = Tuner(hw=HOST_CPU)
            for sig in sigs:
                t.choose(self.CALL, sig,
                         _cands("xla_dense", "xla_hdp", "reference"))
            runs.append(dict(t.decision))
        assert runs[0] == runs[1]

    def test_decision_for_matches_phase(self):
        t = Tuner(hw=HOST_CPU)
        t.choose(self.CALL, _decode_sig(kv=512),
                 _cands("xla_dense", "reference"))
        assert t.decision_for(self.CALL) == "xla_dense"
        assert t.decision_for(AttnCall(mode="prefill",
                                       layout="dense")) is None
        name, est = t.estimate_for(self.CALL)
        assert name == "xla_dense" and est.flops > 0

    def test_sparsity_ema(self):
        t = Tuner(hw=HOST_CPU)
        t.observe_sparsity(0.4, 0.1, 0.6)
        t.observe_sparsity(0.8, 0.1, 0.2)
        sp = t.sparsity_for(_decode_sig(hdp=True))
        assert 0.4 < sp.block < 0.8 and 0.2 < sp.page < 0.6
        # non-HDP signatures never see sparsity discounts
        assert t.sparsity_for(_decode_sig()) == SparsityEstimate()


# ----------------------------------------------------------------- policy
class TestPolicy:
    def test_explicit_policy_pins(self, monkeypatch):
        monkeypatch.setenv(POLICY_ENV, "cost")
        assert effective_policy(AttnSpec(policy="static")) == "static"
        assert effective_policy(AttnSpec(policy="cost")) == "cost"

    def test_auto_policy_reads_env(self, monkeypatch):
        monkeypatch.delenv(POLICY_ENV, raising=False)
        assert effective_policy(AttnSpec()) == "static"
        monkeypatch.setenv(POLICY_ENV, "cost")
        assert effective_policy(AttnSpec()) == "cost"

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError):
            AttnSpec(policy="fastest")

    def test_backend_env_overrides_cost_policy(self, monkeypatch):
        # REPRO_ATTN_BACKEND pins an explicit backend: the oracle CI leg
        # must win over cost ranking or it stops testing the oracle
        monkeypatch.setenv(BACKEND_ENV, "reference")
        call = AttnCall(mode="decode", layout="dense")
        b = resolve_backend(call, AttnSpec(policy="cost"),
                            sig=_decode_sig(kv=128))
        assert b.name == "reference"

    def test_cost_policy_resolves_through_tuner(self, monkeypatch):
        monkeypatch.delenv(BACKEND_ENV, raising=False)
        t = Tuner(hw=HOST_CPU)
        call = AttnCall(mode="decode", layout="dense")
        b = resolve_backend(call, AttnSpec(policy="cost"),
                            sig=_decode_sig(kv=128), tuner=t)
        assert b.name == "xla_dense"
        assert t.misses == 1  # the tuner, not the static order, answered


# ----------------------------------------------------------------- engine
def _prompts(n, lo=4, hi=20, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _run(eng, prompts, max_new=5):
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=max_new))
    return {uid: r.tokens for uid, r in eng.run().items()}


class TestEngineCostPolicy:
    def test_cost_policy_token_identity_and_summary(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        prompts = _prompts(4, seed=7)
        st = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                    attn=AttnSpec(policy="static"))
        params = st.params
        ref = _run(st, prompts)

        co = Engine(cfg, params=params, max_batch=2, max_len=64,
                    prefill_buckets=(16, 32), attn=AttnSpec(policy="cost"))
        assert _run(co, prompts) == ref

        s = co.summary()
        assert s["attn_policy"] == "cost"
        assert {"tuner_hits", "tuner_misses", "tuner_probes",
                "tuner_cached"} <= set(s)
        assert "meas_decode_step_s" in s and s["meas_decode_step_s"] > 0
        if s["tuner_misses"]:  # skipped under REPRO_ATTN_BACKEND pins
            assert s["pred_decode_step_s"] > 0
        assert st.summary()["attn_policy"] == "static"

    def test_probe_flip_bumps_epoch_not_tokens(self, monkeypatch):
        monkeypatch.delenv(BACKEND_ENV, raising=False)
        cfg = reduced(get_config("qwen2-1.5b"))
        prompts = _prompts(3, seed=11)
        st = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                    attn=AttnSpec(policy="static"))
        ref = _run(st, prompts)

        co = Engine(cfg, params=st.params, max_batch=2, max_len=64,
                    prefill_buckets=(16, 32), attn=AttnSpec(policy="cost"))
        # force "a probe flipped something" every flush: each step must
        # re-trace (epoch bump) and still commit identical tokens
        co.tuner.flush_probes = lambda: True
        assert _run(co, prompts) == ref
        assert co._attn_epoch > 0

    def test_explicit_tuner_is_installed(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        mine = Tuner(hw=HOST_CPU)
        eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16,),
                     attn=AttnSpec(policy="cost"), tuner=mine)
        assert eng.tuner is mine and default_tuner() is mine


class _ForcedCtl:
    """SpecController stand-in replaying a fixed (k, profile) schedule."""

    def __init__(self, ctl, ks):
        self._ctl = ctl
        self._ks = list(ks)
        self.plans = []

    def plan(self):
        k = self._ks.pop(0) if self._ks else 1
        tier = {1: self._ctl.conservative, 2: self._ctl.base}
        profile = tier.get(k, self._ctl.aggressive)
        self.plans.append(k)
        return k, profile

    def update(self, accepted, drafted):
        self._ctl.update(accepted, drafted)

    def summary(self):
        return self._ctl.summary()


class TestAdaptiveSpec:
    def test_requires_spec_decode(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        with pytest.raises(ValueError, match="adaptive_spec"):
            Engine(cfg, spec_decode=False, adaptive_spec=True)

    def test_adaptive_rounds_token_identical_to_greedy(self):
        cfg = reduced(get_config("qwen2-1.5b"))
        prompts = _prompts(3, seed=5)
        base = Engine(cfg, max_batch=2, max_len=64,
                      prefill_buckets=(16, 32), spec_decode=False)
        ref = _run(base, prompts, max_new=8)

        ad = Engine(cfg, params=base.params, max_batch=2, max_len=64,
                    prefill_buckets=(16, 32), spec_decode=True,
                    draft_len=4, adaptive_spec=True)
        assert _run(ad, prompts, max_new=8) == ref
        sc = ad.spec_ctl.summary()
        assert sc["rounds"] > 0 and sc["draft_len_mean"] >= 1.0
        s = ad.summary()
        assert s["adaptive_spec"] and "acceptance_ema" in s

    @pytest.mark.parametrize("schedule", [
        [1, 1, 1, 1, 1, 1, 1, 1, 1, 1],          # speculation forced off
        [4, 1, 2, 4, 1, 3, 2, 1, 4, 2],          # thrashing k + profiles
    ])
    def test_forced_schedule_token_identity(self, schedule):
        cfg = reduced(get_config("qwen2-1.5b"))
        prompts = _prompts(2, seed=9)
        base = Engine(cfg, max_batch=2, max_len=64,
                      prefill_buckets=(16, 32), spec_decode=False)
        ref = _run(base, prompts, max_new=6)

        ad = Engine(cfg, params=base.params, max_batch=2, max_len=64,
                    prefill_buckets=(16, 32), spec_decode=True,
                    draft_len=4, adaptive_spec=True)
        forced = _ForcedCtl(ad.spec_ctl, schedule)
        ad.spec_ctl = forced
        assert _run(ad, prompts, max_new=6) == ref
        assert forced.plans[:3] == schedule[:3]


# ---------------------------------------------------------- SpecController
class TestSpecController:
    BASE = DraftProfile(scores="scout")

    def _ctl(self, **kw):
        return SpecController(self.BASE, HDP, SpecConfig(**kw))

    def test_optimistic_start_drafts_full_length(self):
        k, profile = self._ctl(k_max=4).plan()
        assert k == 4 and profile.rho_b == pytest.approx(0.6)
        assert profile.tau_h == pytest.approx(0.05)
        assert profile.scores == "scout"  # pool layout never varies

    def test_collapse_walks_down_to_k1_conservative(self):
        ctl = self._ctl(k_max=4)
        for _ in range(12):
            ctl.update(0, 3)
        assert ctl.ema < ctl.cfg.conservative_below
        k, profile = ctl.plan()
        assert k == 1
        assert profile is ctl.conservative
        assert profile.rho_b is None and profile.tau_h is None

    def test_recovery_raises_k_again(self):
        ctl = self._ctl(k_max=4)
        for _ in range(12):
            ctl.update(0, 3)
        for _ in range(20):
            ctl.update(3, 3)
        k, profile = ctl.plan()
        assert k == 4 and profile is ctl.aggressive

    def test_zero_draft_rounds_leave_ema_untouched(self):
        ctl = self._ctl()
        ema0 = ctl.ema
        ctl.update(0, 0)
        ctl.update(5, -1)
        assert ctl.ema == ema0 and ctl.rounds == 2
        assert ctl.drafted_total == 0

    def test_aggressive_rho_clamped(self):
        hot = HDP.replace(rho_b=0.93)
        ctl = SpecController(DraftProfile(), hot, SpecConfig())
        assert ctl.aggressive.rho_b == pytest.approx(0.95)

    def test_base_overrides_beat_hdp_fallback(self):
        ctl = SpecController(DraftProfile(rho_b=0.2, tau_h=0.1), HDP,
                             SpecConfig(rho_step=0.1, tau_step=0.05))
        assert ctl.aggressive.rho_b == pytest.approx(0.3)
        assert ctl.aggressive.tau_h == pytest.approx(0.15)

    def test_summary_and_rates(self):
        ctl = self._ctl()
        ctl.plan()
        ctl.update(2, 3)
        s = ctl.summary()
        assert s["rounds"] == 1 and s["drafted"] == 3 and s["accepted"] == 2
        assert s["acceptance_rate"] == pytest.approx(2 / 3)
        assert s["draft_len_mean"] >= 1.0

    def test_config_validation(self):
        with pytest.raises(ValueError):
            SpecConfig(k_min=3, k_max=2)
        with pytest.raises(ValueError):
            SpecConfig(k_min=0)
        with pytest.raises(ValueError):
            SpecConfig(beta=1.0)
