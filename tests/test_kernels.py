"""Per-kernel validation: shape/dtype sweeps, interpret mode vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HDPConfig, hdp_attention
from repro.core.quant import quantize_fixed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hdp_block_attn import hdp_block_sparse_attention
from repro.kernels.hdp_scout import hdp_scout
from repro.kernels.ops import hdp_attention_tpu


def rnd(*shape, seed=0, scale=2.0, dtype=jnp.float32):
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# ------------------------------------------------------------------ flash
class TestFlashKernel:
    @pytest.mark.parametrize("shape", [
        (1, 2, 128, 64),
        pytest.param((2, 3, 256, 128), marks=pytest.mark.slow),
        pytest.param((1, 1, 160, 64), marks=pytest.mark.slow),  # ragged S
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, shape, causal):
        q, k, v = (rnd(*shape, seed=s) for s in (1, 2, 3))
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = (rnd(1, 2, 128, 64, seed=s, dtype=dtype) for s in (4, 5, 6))
        out = flash_attention(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)


# ------------------------------------------------------------------ scout
class TestScoutKernel:
    @pytest.mark.parametrize("shape", [
        (1, 2, 128, 64),
        pytest.param((2, 2, 256, 32), marks=pytest.mark.slow),
    ])
    @pytest.mark.parametrize("rho", [0.5, -0.5])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, shape, rho, causal):
        iq = jnp.trunc(rnd(*shape, seed=7, scale=3.0))
        ik = jnp.trunc(rnd(*shape, seed=8, scale=3.0))
        theta, keep, th_head = hdp_scout(
            iq, ik, rho_b=rho, block_q=64, block_k=64, causal=causal,
            interpret=True)
        theta_r, keep_r, th_head_r = ref.hdp_scout_ref(
            iq, ik, block_q=64, block_k=64, rho_b=rho, causal=causal)
        np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_r),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_r))
        np.testing.assert_allclose(np.asarray(th_head),
                                   np.asarray(th_head_r), rtol=1e-5)

    def test_chunked_kv_equals_single_chunk(self):
        iq = jnp.trunc(rnd(1, 1, 256, 64, seed=9, scale=3.0))
        ik = jnp.trunc(rnd(1, 1, 256, 64, seed=10, scale=3.0))
        a = hdp_scout(iq, ik, rho_b=0.5, block_q=64, block_k=64,
                      chunk_blocks=1, interpret=True)
        b = hdp_scout(iq, ik, rho_b=0.5, block_q=64, block_k=64,
                      chunk_blocks=4, interpret=True)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


# ------------------------------------------------------------- block attn
class TestBlockAttnKernel:
    def _mk(self, B=1, H=2, S=256, hd=64, seed=0):
        q = quantize_fixed(rnd(B, H, S, hd, seed=seed))
        k = quantize_fixed(rnd(B, H, S, hd, seed=seed + 1))
        v = rnd(B, H, S, hd, seed=seed + 2)
        return q, k, v

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("approx", [True, False])
    def test_full_keep_matches_masked_ref(self, causal, approx):
        q, k, v = self._mk(seed=11)
        nq = nk = 256 // 64
        keep = jnp.ones((1, 2, nq, nk), bool)
        theta = jnp.ones((1, 2, nq, nk))
        idx, cnt = ref.keep_mask_to_indices(keep, theta, nk)
        hk = jnp.ones((1, 2), bool)
        out = hdp_block_sparse_attention(
            q, k, v, idx, cnt, hk, causal=causal, approx=approx,
            block_q=64, block_k=64, interpret=True)
        want = ref.hdp_block_attn_ref(q, k, v, keep, block_q=64, block_k=64,
                                      causal=causal, approx=approx)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_sparse_keep_matches_ref(self):
        q, k, v = self._mk(seed=13)
        iq, ik = jnp.trunc(q), jnp.trunc(k)
        theta, keep, _ = ref.hdp_scout_ref(iq, ik, block_q=64, block_k=64,
                                           rho_b=0.5, causal=True)
        idx, cnt = ref.keep_mask_to_indices(keep, theta, keep.shape[-1])
        hk = jnp.ones((1, 2), bool)
        out = hdp_block_sparse_attention(
            q, k, v, idx, cnt, hk, causal=True, approx=True,
            block_q=64, block_k=64, interpret=True)
        want = ref.hdp_block_attn_ref(q, k, v, keep, block_q=64, block_k=64,
                                      causal=True, approx=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_head_gate_zeroes_output(self):
        q, k, v = self._mk(seed=17)
        nq = nk = 256 // 64
        keep = jnp.ones((1, 2, nq, nk), bool)
        idx, cnt = ref.keep_mask_to_indices(keep, jnp.ones_like(keep, jnp.float32), nk)
        hk = jnp.array([[True, False]])
        out = hdp_block_sparse_attention(q, k, v, idx, cnt, hk, causal=True,
                                         block_q=64, block_k=64, interpret=True)
        assert float(jnp.abs(out[0, 1]).max()) == 0.0
        assert float(jnp.abs(out[0, 0]).max()) > 0.0


# ----------------------------------------------------- end-to-end pipeline
class TestHDPPipeline:
    def test_pipeline_matches_core_hdp(self):
        """kernel pipeline == core.hdp_attention with the same TPU blocks."""
        B, H, S, hd = 1, 2, 256, 64
        q, k, v = (rnd(B, H, S, hd, seed=s) for s in (19, 20, 21))
        cfg = HDPConfig(block_q=64, block_k=64, rho_b=0.5, tau_h=0.0,
                        causal=True, normalize_head_score=True)
        out_k, stats_k = hdp_attention_tpu(q, k, v, cfg, interpret=True,
                                           return_stats=True)
        out_c, stats_c = hdp_attention(q, k, v, cfg)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_c),
                                   rtol=3e-3, atol=3e-3)
        assert abs(float(stats_k["head_sparsity"])
                   - float(stats_c.head_sparsity)) < 1e-6

    def test_max_keep_cap_degrades_gracefully(self):
        B, H, S, hd = 1, 2, 256, 64
        q, k, v = (rnd(B, H, S, hd, seed=s) for s in (22, 23, 24))
        cfg = HDPConfig(block_q=64, block_k=64, rho_b=0.5, causal=True,
                        normalize_head_score=True)
        exact, _ = hdp_attention_tpu(q, k, v, cfg, interpret=True)
        capped, _ = hdp_attention_tpu(q, k, v, cfg, max_keep=2,
                                      interpret=True)
        # capped keeps the top-theta blocks; output stays finite & close-ish
        assert bool(jnp.isfinite(capped).all())
        cos = float((exact * capped).sum() /
                    (jnp.linalg.norm(exact) * jnp.linalg.norm(capped) + 1e-9))
        assert cos > 0.8
