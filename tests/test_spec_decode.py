"""Self-speculative decode: draft + batched verify + frontier rollback.

Load-bearing guarantees pinned here:

* speculative decode is token-for-token identical to horizon-1 greedy
  decode across paged and dense layouts, at every draft length —
  including mid-round EOS, staggered budgets, and degenerate drafts
  whose proposals are never accepted (the verify's exact tokens carry
  every round);
* the multi-query verify's per-query-row scout reproduces the sequential
  single-step masks exactly (unit conformance, kernel path included);
* the draft pass never reads the full-precision K pool: its scores come
  from the two int8 scout copies (NaN-poisoning all of k_pages leaves
  the draft's output unchanged);
* rejected speculative writes are rolled back by NaN-poisoning their K —
  the frontier invariant (rewrite-before-read) is self-enforcing, and
  generation still completes byte-identically through the poison;
* rollback composes with prefix-cache sharing: a COW'd tail page absorbs
  the speculative staging while the shared original's bytes never move,
  and sub-floor pages stay fenced;
* the speculative round donates the serving cache and take()/put() guard
  stale handles, exactly like the fused horizon loop;
* spec_rounds / draft_tokens / accepted_tokens count only slots that
  really decoded (parked slots are masked), and the env/kwarg plumbing
  (REPRO_SPEC_DECODE / REPRO_DRAFT_LEN) mirrors the horizon knobs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec, DraftProfile
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.models.attention import hdp_paged_decode_attention, scout_int8
from repro.serving import Engine, Request
from repro.serving.kv_cache import DonatedCacheError

F32 = jnp.float32

#: a draft whose head gate kills every head: proposals degenerate to a
#: constant token, so almost every round rejects almost everything —
#: the zero-acceptance stress shape
DEAD_DRAFT = DraftProfile(tau_h=1e9)


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _qwen(calib="none", enabled=True):
    cfg = reduced(get_config("qwen2-1.5b"))
    return cfg.replace(hdp=cfg.hdp.replace(enabled=enabled, calib=calib))


def _serve(cfg, params, prompts, *, max_new=5, stagger=True, **kw):
    eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                 prefill_buckets=(16, 32), **kw)
    for uid, p in enumerate(prompts):
        mn = max_new + (uid % 3 if stagger else 0)
        eng.submit(Request(uid, p, max_new_tokens=mn))
    res = eng.run()
    return eng, {u: r.tokens for u, r in res.items()}


# ------------------------------------------------------------ token identity
@pytest.mark.parametrize("layout", [
    "paged",
    pytest.param("dense", marks=pytest.mark.slow),
])
def test_spec_matches_single_step(layout):
    """Staggered budgets force slots to finish mid-round while their batch
    neighbors keep speculating — output must not notice, at any k."""
    cfg = _qwen()
    kw = {"attn": AttnSpec(layout=layout)}
    prompts = _prompts(4, seed=3)
    eng, base = _serve(cfg, None, prompts, spec_decode=False,
                       decode_horizon=1, **kw)
    for k in (1, 3, 4, 8):
        _, got = _serve(cfg, eng.params, prompts, spec_decode=True,
                        draft_len=k, **kw)
        assert got == base, f"{layout} draft_len={k}: {got} != {base}"


def test_spec_matches_single_step_no_hdp():
    """With HDP off there is no scout to draft with: the draft degrades
    to an exact proposer and the round must still be identity-preserving.
    An exact self-draft under greedy decode must also be fully accepted —
    a lower rate would mean the degraded draft reads state the staging
    path skipped (the K-write skip is HDP-gated for exactly this)."""
    cfg = _qwen(enabled=False)
    prompts = _prompts(3, seed=5)
    eng, base = _serve(cfg, None, prompts, spec_decode=False,
                       decode_horizon=1, stagger=False)
    # uniform budgets: with staggered budgets a slot drafts past its own
    # remaining budget (the round width tracks the LONGEST) and those
    # never-committable proposals honestly count against acceptance
    e2, got = _serve(cfg, eng.params, prompts, spec_decode=True, draft_len=4,
                     stagger=False)
    assert got == base
    assert e2.summary()["acceptance_rate"] == 1.0


def test_eos_mid_round_matches_single_step():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=1, max_len=64, spec_decode=False,
                 decode_horizon=1)
    eng.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8))
    ref = eng.run()[0].tokens
    j = next((i for i in range(1, len(ref)) if ref[i] not in ref[:i]), None)
    if j is None:
        pytest.skip("degenerate generation: all tokens identical")
    for k in (2, 4, 8):
        e2 = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                    spec_decode=True, draft_len=k)
        e2.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8,
                          eos_id=ref[j]))
        assert e2.run()[0].tokens == ref[:j + 1], f"draft_len={k}"


def test_zero_acceptance_rounds_still_identical():
    """A draft whose proposals are (nearly) never accepted costs speed,
    never correctness: every committed token is the verify's exact one."""
    cfg = _qwen()
    prompts = _prompts(4, seed=7)
    eng, base = _serve(cfg, None, prompts, spec_decode=False,
                       decode_horizon=1)
    e2, got = _serve(cfg, eng.params, prompts, spec_decode=True,
                     draft_len=4, draft_profile=DEAD_DRAFT)
    assert got == base
    s = e2.summary()
    # the dead draft's constant proposals may occasionally collide with
    # the exact token — but most must be rejected
    assert s["acceptance_rate"] < 0.5
    assert s["spec_rounds"] > 0


# -------------------------------------------------------------- env plumbing
def test_spec_env_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_SPEC_DECODE", "1")
    monkeypatch.setenv("REPRO_DRAFT_LEN", "3")
    eng = Engine(_qwen(), max_batch=1, max_len=32)
    assert eng.spec and eng.draft_len == 3
    # explicit kwargs win over the env
    eng = Engine(_qwen(), max_batch=1, max_len=32, spec_decode=False,
                 draft_len=5)
    assert not eng.spec and eng.draft_len == 5
    with pytest.raises(ValueError):
        Engine(_qwen(), max_batch=1, max_len=32, spec_decode=True,
               draft_len=0)


def test_spec_env_degrades_for_recurrent_families(monkeypatch):
    cfg = reduced(get_config("rwkv6-3b"))
    monkeypatch.setenv("REPRO_SPEC_DECODE", "1")
    assert not Engine(cfg, max_batch=1, max_len=32).spec  # env degrades
    with pytest.raises(ValueError, match="spec_decode"):
        Engine(cfg, max_batch=1, max_len=32, spec_decode=True)  # explicit raises


def test_spec_pins_static_calibration():
    """Speculative staging leaves garbage past the frontier; a
    data-dependent calibration scale would see it — spec engines pin the
    static grid on every layout, like the paged write-time scout does."""
    eng = Engine(_qwen(calib="max"), max_batch=1, max_len=32,
                 attn=AttnSpec(layout="dense"), spec_decode=True)
    assert eng.cfg.hdp.calib == "none"


# ------------------------------------------------------------------ counters
def test_spec_counters_masked_for_parked_slots():
    """One request on a 2-slot engine: the parked slot must not inflate
    draft/accept accounting, and the identities between the counters and
    the emitted tokens must hold exactly."""
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 spec_decode=True, draft_len=4)
    eng.submit(Request(0, _prompts(1, seed=9)[0], max_new_tokens=7))
    res = eng.run()
    s = eng.summary()
    assert len(res[0].tokens) == 7
    assert s["spec_decode"] and s["draft_len"] == 4
    # one active slot: at most (draft_len-1) drafts per round (the round
    # width clamps to the remaining budget), parked slot unseen
    assert 0 < s["draft_tokens"] <= 3 * s["spec_rounds"]
    # every round commits >= 1 exact token; the rest are accepted drafts
    assert s["tokens_out"] == s["accepted_tokens"] + s["spec_rounds"]
    assert 0.0 <= s["acceptance_rate"] <= 1.0
    assert s["attn_backend_draft"]
    assert s["attn_backend_verify"]


# -------------------------------------------------- draft bandwidth contract
def _paged_inputs(seed, hdp, n_pages, B=2, N=2, G=2, hd=8, Sq=1):
    ps = hdp.block_k
    P = 1 + B * n_pages
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, N, G, Sq, hd), F32)
    ks = jax.random.normal(jax.random.fold_in(rng, 1), (P, ps, N, hd), F32)
    vs = jax.random.normal(jax.random.fold_in(rng, 2), (P, ps, N, hd), F32)
    ik = scout_int8(ks, hdp)
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n_pages)
    base = n_pages * ps - Sq
    pos = base + jnp.arange(Sq, dtype=jnp.int32)[None] \
        * jnp.ones((B, 1), jnp.int32)
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(n_pages * ps)
    k_pos = jnp.where(ar[None] <= pos[:, -1:], ar, -1)[:, None, None, :]
    return q, ks, vs, ik, table, q_pos, k_pos


def test_draft_never_reads_fp_k_pool():
    """The scout-scores draft reads only the int8 copies + surviving V:
    NaN-poisoning the ENTIRE full-precision K pool changes nothing."""
    from repro.models.attention import scout_frac_int8
    hdp = HDPConfig(block_q=1, block_k=4, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    q, ks, vs, ik, table, q_pos, k_pos = _paged_inputs(0, hdp, n_pages=6)
    fk = scout_frac_int8(ks, hdp)
    for profile in (DraftProfile(), DraftProfile(scores="int")):
        clean, _ = hdp_paged_decode_attention(
            q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp,
            draft=profile, fk_pool=fk)
        poisoned, _ = hdp_paged_decode_attention(
            q, jnp.full_like(ks, jnp.nan), vs, ik, table, q_pos=q_pos,
            k_pos=k_pos, hdp=hdp, draft=profile, fk_pool=fk)
        assert bool(jnp.isfinite(poisoned).all()), \
            f"{profile.scores}: draft read the full-precision K pool"
        np.testing.assert_array_equal(np.asarray(clean),
                                      np.asarray(poisoned))


def test_scout_draft_requires_frac_pool():
    """The scout score mode promises never to read the fp K pool; without
    the f_scout pool its IQ·FK^ term is underivable — misuse must raise,
    not silently serve lower-fidelity drafts."""
    hdp = HDPConfig(block_q=1, block_k=4, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    q, ks, vs, ik, table, q_pos, k_pos = _paged_inputs(2, hdp, n_pages=4)
    with pytest.raises(ValueError, match="f_scout"):
        hdp_paged_decode_attention(q, ks, vs, ik, table, q_pos=q_pos,
                                   k_pos=k_pos, hdp=hdp,
                                   draft=DraftProfile())


# --------------------------------------------------- per-query verify scout
# pallas_block documents an Sq-unaware kernel: its per-query calls fall
# back to the xla stage, which this conformance row pins
@pytest.mark.parametrize("stage3", ["xla", "pallas_paged", "pallas_block"])
def test_verify_rows_match_sequential_steps(stage3):
    """Row j of a multi-query verify call must equal the single-step
    decode at position j — keep masks, head gates and softmax alike
    (exact-match acceptance hangs off this equivalence)."""
    hdp = HDPConfig(block_q=1, block_k=4, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    Sq = 3
    q, ks, vs, ik, table, q_pos, k_pos = _paged_inputs(
        4, hdp, n_pages=4, Sq=Sq)
    multi, _ = hdp_paged_decode_attention(
        q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp,
        per_query=True, stage3=stage3)
    for j in range(Sq):
        qj = q[:, :, :, j:j + 1]
        pj = q_pos[..., j:j + 1]
        ar = jnp.arange(k_pos.shape[-1])
        kj = jnp.where(ar[None, None, None, :] <= pj, ar, -1)
        single, _ = hdp_paged_decode_attention(
            qj, ks, vs, ik, table, q_pos=pj, k_pos=kj, hdp=hdp,
            stage3=stage3)
        np.testing.assert_allclose(
            np.asarray(multi[:, :, :, j]), np.asarray(single[:, :, :, 0]),
            atol=2e-5, rtol=2e-5,
            err_msg=f"{stage3}: verify row {j} != sequential step")


def test_verify_call_resolves_through_registry():
    """The verify AttnCall resolves to backends that declared multi-query
    capability; the draft call never lands on a Pallas kernel."""
    from repro.attention import get_backend, resolve_backend
    from repro.models.attention import build_attn_call
    cfg = _qwen()
    ver = build_attn_call(cfg, mode="decode", paged=True, per_slot=True,
                          verify=True)
    assert get_backend("paged_hdp_decode").supports(ver)
    assert get_backend("pallas_paged_decode").supports(ver)
    assert not get_backend("pallas_hdp_block").supports(ver)
    assert resolve_backend(ver, AttnSpec(backend="xla")).name \
        == "paged_hdp_decode"
    drf = build_attn_call(cfg, mode="decode", paged=True, per_slot=True,
                          draft=DraftProfile())
    assert not get_backend("pallas_paged_decode").supports(drf)
    assert resolve_backend(drf, AttnSpec(backend="pallas")).name \
        == "paged_hdp_decode"          # kernels fall back for draft calls


# --------------------------------------------------------- rollback + poison
def test_rejected_speculative_writes_are_poisoned():
    """After a round with rejections, the K of every rejected staged
    position is poisoned (the rollback fence: NaN for the fp32 pool, the
    -128 sentinel code for the quantized one) — and generation still
    drains byte-identically through it (rewrite-before-read holds)."""
    cfg = _qwen()
    prompt = _prompts(1, seed=13)[0]
    base = Engine(cfg, max_batch=1, max_len=64, spec_decode=False,
                  decode_horizon=1)
    base.submit(Request(0, prompt, max_new_tokens=8))
    ref = base.run()[0].tokens

    k = 4
    eng = Engine(cfg, params=base.params, max_batch=1, max_len=64,
                 spec_decode=True, draft_len=k, draft_profile=DEAD_DRAFT)
    eng.submit(Request(0, prompt, max_new_tokens=8))
    start = len(prompt) - 1
    eng.step()                               # admit + first round
    committed = len(eng._active[0]["generated"]) if 0 in eng._active else 8
    assert committed < k, "dead draft unexpectedly fully accepted"
    ps = eng.pages.page_size
    pages = eng.pages.slot_pages(0)
    poisoned = np.asarray(eng.pages.poison_view())   # dtype-independent
    for p in range(start + committed, start + k):
        page, off = pages[p // ps], p % ps
        assert poisoned[:, page, off].all(), \
            f"rejected staged position {p} not poisoned"
    # committed frontier (last committed token's write) stays clean
    last = start + committed - 1
    assert not poisoned[:, pages[last // ps], last % ps].any()
    assert eng.run()[0].tokens == ref


def test_spec_rollback_respects_cow_and_write_floor():
    """Full-prompt prefix hit: the resume + speculative staging land in
    the COW'd tail page; the shared original's bytes never change even
    while rounds stage and roll back across it."""
    cfg = _qwen()
    rng = np.random.default_rng(11)
    donor = rng.integers(1, 250, size=13).tolist()
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 prefix_cache=True, spec_decode=True, draft_len=4)
    eng.submit(Request(0, donor, max_new_tokens=3))
    eng.run()
    matched = eng.prefix.match(donor[:12])
    tail_page = matched[-1]
    eng.pages.allocator.unref(matched)
    before = np.asarray(eng.pages.cache["k_pages"][:, tail_page])

    eng.submit(Request(1, donor[:12], max_new_tokens=3))   # full hit -> COW
    res = eng.run()
    assert eng.summary()["cow_copies"] == 1
    after = np.asarray(eng.pages.cache["k_pages"][:, tail_page])
    np.testing.assert_array_equal(before, after)

    solo = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                  prefill_buckets=(16, 32), prefix_cache=False,
                  spec_decode=False, decode_horizon=1)
    solo.submit(Request(9, donor[:12], max_new_tokens=3))
    assert res[1].tokens == solo.run()[9].tokens


@pytest.mark.parametrize("prefix_cache", [False, True])
def test_spec_prefix_cache_identity(prefix_cache):
    """Shared-prefix workload: speculative decode with the prefix cache
    on/off is byte-identical to the non-speculative engine."""
    cfg = _qwen()
    rng = np.random.default_rng(17)
    shared = rng.integers(1, 250, size=16).tolist()
    prompts = [shared + rng.integers(1, 250, size=4 + i).tolist()
               for i in range(3)] + [shared[:12]]
    outs = []
    params = None
    for spec in (False, True):
        eng = Engine(cfg, params=params, max_batch=2, max_len=96,
                     prefill_buckets=(16, 32), prefix_cache=prefix_cache,
                     spec_decode=spec, draft_len=4, decode_horizon=1)
        params = eng.params
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=4))
        outs.append({u: r.tokens for u, r in eng.run().items()})
    assert outs[0] == outs[1]


# ----------------------------------------------------------------- donation
def test_spec_round_donates_cache():
    """The speculative round jit aliases the page pool in place — after
    one round the pre-round pool buffer is deleted, and stale handles
    raise through take()/put()."""
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=64, spec_decode=True, draft_len=4)
    for uid, p in enumerate(_prompts(2, seed=5)):
        eng.submit(Request(uid, p, max_new_tokens=4))
    eng._admit()
    old = eng.pages.cache
    eng.step()
    assert all(old[k].is_deleted() for k in old), \
        "donation rejected: speculative round allocated a second page pool"
    cache = eng.pages.take()
    with pytest.raises(DonatedCacheError):
        _ = eng.pages.cache
    eng.pages.put(cache)
    eng.run()

    dense = Engine(cfg, params=eng.params, max_batch=2, max_len=64,
                   attn=AttnSpec(layout="dense"), spec_decode=True,
                   draft_len=4)
    dense.submit(Request(0, _prompts(1, seed=5)[0], max_new_tokens=4))
    dense._admit()
    old_k = dense.slots.cache["k"]
    dense.step()
    assert old_k.is_deleted()
    dense.run()
