"""Block-paged KV cache + HDP-aware paged decode.

Load-bearing guarantees pinned here:

* page alloc/free/reuse stays consistent under continuous-batching churn
  (no page ever owned by two slots, free list conserved);
* paged decode is token-for-token identical to the dense `SlotCache`
  decode — with HDP off, and with HDP on under the static fixed-point
  grid (calib="none", the write-time-scout regime the paged backend
  always operates in);
* pruned pages are NEVER gathered: poisoning their full-precision K/V
  with NaN cannot change the output (the FUM contract);
* batched bucketed prefill groups same-bucket requests into fewer jit
  calls, and chunked prefill of a long prompt matches one-shot prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.core.hdp import decode_scout
from repro.models.attention import (_fixed_split, _mask_bias,
                                    hdp_paged_decode_attention, scout_int8)
from repro.serving import Engine, Request
from repro.serving.kv_cache import PagedKVCache

F32 = jnp.float32


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _qwen(calib=None, enabled=True):
    cfg = reduced(get_config("qwen2-1.5b"))
    hdp = cfg.hdp.replace(enabled=enabled)
    if calib is not None:
        hdp = hdp.replace(calib=calib)
    return cfg.replace(hdp=hdp)


def _serve(cfg, params, prompts, max_new=5, **kw):
    eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                 prefill_buckets=(16, 32), **kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=max_new))
    res = eng.run()
    return eng, {u: r.tokens for u, r in res.items()}


# ---------------------------------------------------------------- pool unit
def test_page_alloc_free_reuse():
    cfg = _qwen()
    pool = PagedKVCache(cfg, batch=3, max_len=32)  # block_k=2 -> 16 pages/slot
    total_free = len(pool._free)
    a = pool.alloc(0, 10)           # 5 pages
    b = pool.alloc(1, 3)            # 2 pages
    assert len(a) == 5 and len(b) == 2
    assert not set(a) & set(b), "pages shared between slots"
    assert 0 not in a + b, "scratch page must never be allocated"
    assert (pool._table[0, :5] == a).all() and (pool._table[0, 5:] == 0).all()
    pool.free(0)
    assert (pool._table[0] == 0).all()
    c = pool.alloc(2, 12)           # 6 pages; reuses slot 0's freed pages
    assert set(c) & set(a), "freed pages must be reused"
    pool.free(1)
    pool.free(2)
    assert len(pool._free) == total_free, "free list not conserved"
    assert pool.pages_in_use == 0
    with pytest.raises(ValueError):
        pool.alloc(0, 33)           # beyond max_len


def test_pool_exhaustion_is_impossible_within_capacity():
    cfg = _qwen()
    pool = PagedKVCache(cfg, batch=2, max_len=16)
    pool.alloc(0, 16)
    pool.alloc(1, 16)               # full occupancy still fits
    assert pool.pages_in_use == 2 * pool.pages_per_slot


def test_engine_churn_recycles_pages():
    # prefix_cache=False pins the pure-recycling invariant: with the
    # cache on, finished prompts intentionally stay resident (see
    # tests/test_prefix_cache.py for the shared-substrate invariants)
    cfg = _qwen(calib="none")
    eng, toks = _serve(cfg, None, _prompts(6, seed=1), max_new=3,
                       prefix_cache=False)
    assert len(toks) == 6 and all(len(t) == 3 for t in toks.values())
    # 6 requests through 2 slots: peak occupancy must stay bounded by the
    # two-slot working set, i.e. pages were freed and reused
    assert eng.pages.peak_pages <= 2 * eng.pages.pages_per_slot
    assert eng.pages.pages_in_use == 0  # all freed at drain


# ------------------------------------------------------- paged == dense
@pytest.mark.parametrize("mode", ["hdp_off", "hdp_calib_none", "hdp_stock"])
def test_paged_decode_equals_dense_decode(mode):
    """Token-for-token identity on the seed qwen2 reduced config.

    "hdp_stock" serves the config exactly as registered (calib="max"):
    the paged engine pins calib="none" internally, so it must match a
    dense engine given the same effective (calib-free) config."""
    cfg = _qwen(enabled=False) if mode == "hdp_off" else \
        _qwen() if mode == "hdp_stock" else _qwen(calib="none")
    prompts = _prompts(4, seed=3)
    # cross-layout identity needs the fp32 pool: the default int8 store
    # round-trips K/V at prefill time, which the dense cache never does
    eng, paged = _serve(cfg, None, prompts, attn=AttnSpec(kv_dtype="fp32"))
    if mode == "hdp_stock":
        assert eng.cfg.hdp.calib == "none", "paged engine must pin calib"
        cfg = _qwen(calib="none")
    _, dense = _serve(cfg, eng.params, prompts, attn=AttnSpec(layout="dense"))
    assert paged == dense, f"{mode}: paged {paged} != dense {dense}"


def test_paged_engine_emits_page_stats():
    cfg = _qwen()   # stock calibration: stats path, no token-equality claim
    eng, toks = _serve(cfg, None, _prompts(3, seed=5), collect_stats=True)
    s = eng.summary()
    assert s["stat_samples"] > 0
    assert 0.0 <= s["page_sparsity"] <= 1.0
    assert s["cache_backend"] == "paged"
    assert s["cache_bytes"] <= s["cache_bytes_pool"]


# ------------------------------------------------------------ FUM contract
def test_pruned_pages_never_gathered():
    """Poisoning pruned pages' full-precision K/V cannot change the output."""
    rng = jax.random.PRNGKey(0)
    B, N, G, hd, ps, nP = 2, 2, 2, 8, 4, 8
    P = 1 + B * nP
    hdp = HDPConfig(block_q=1, block_k=ps, rho_b=0.5, causal=True,
                    head_pruning=False, calib="none")
    ks = jax.random.normal(jax.random.fold_in(rng, 1), (P, ps, N, hd), F32)
    vs = jax.random.normal(jax.random.fold_in(rng, 2), (P, ps, N, hd), F32)
    ik = scout_int8(ks, hdp)
    q = jax.random.normal(jax.random.fold_in(rng, 3), (B, N, G, 1, hd), F32)
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, nP)
    pos = jnp.full((B, 1), nP * ps - 1, jnp.int32)   # every page visible
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(nP * ps)
    k_pos = jnp.where(ar[None] <= pos, ar, -1)[:, None, None, :]

    out, _ = hdp_paged_decode_attention(
        q, ks, vs, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp)

    # reconstruct the keep mask exactly as the kernel does
    ik_full = ik[table].reshape(B, nP * ps, N, hd).astype(F32)
    _, iq, _ = _fixed_split(q, hdp)
    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik_full,
                       preferred_element_type=F32)
    valid = _mask_bias(q_pos, k_pos, hdp.causal, 0)
    keep, _, _, _, head_kept = decode_scout(s_int, valid, hdp)
    fetched = (keep & head_kept[..., None]).any(axis=(1, 2))     # [B, nP]
    pruned_pages = np.asarray(jnp.where(fetched, 0, table)).ravel()
    pruned_pages = pruned_pages[pruned_pages > 0]
    assert pruned_pages.size > 0, "test needs some pruned pages; lower rho_b"

    poison = jnp.asarray(pruned_pages)
    ks_bad = ks.at[poison].set(jnp.nan)
    vs_bad = vs.at[poison].set(jnp.nan)
    out_bad, _ = hdp_paged_decode_attention(
        q, ks_bad, vs_bad, ik, table, q_pos=q_pos, k_pos=k_pos, hdp=hdp)
    assert bool(jnp.isfinite(out_bad).all()), \
        "NaN leaked: a pruned page was gathered"
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_bad))


# ------------------------------------------------- batched/chunked prefill
@pytest.mark.slow  # spins one batched + four solo engines
def test_batched_prefill_groups_buckets():
    cfg = _qwen(calib="none")
    # 4 same-bucket prompts over 4 slots -> a single stacked prefill call
    prompts = [_prompts(1, lo=10, hi=14, seed=s)[0] for s in range(4)]
    eng = Engine(cfg, max_batch=4, max_len=64, prefill_buckets=(16, 32))
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=3))
    res = eng.run()
    assert eng.metrics["prefill_calls"] == 1
    # each request must still decode exactly like a solo engine
    for uid, p in enumerate(prompts):
        solo = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                      prefill_buckets=(16, 32))
        solo.submit(Request(99, p, max_new_tokens=3))
        assert res[uid].tokens == solo.run()[99].tokens


def test_chunked_prefill_matches_one_shot():
    # exact at tau_h=0 (all registered configs): with tau_h > 0, HDP's
    # early head gate applies per forward call, so chunked gating may
    # differ from whole-prompt gating (documented in Engine._prefill_long)
    cfg = _qwen(calib="none")
    assert cfg.hdp.tau_h == 0.0
    prompt = _prompts(1, lo=40, hi=41, seed=9)[0]     # 40 > largest bucket
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(8, 16))
    eng.submit(Request(0, prompt, max_new_tokens=5))
    chunked = eng.run()[0].tokens
    one = Engine(cfg, params=eng.params, max_batch=2, max_len=64,
                 prefill_buckets=(64,))
    one.submit(Request(0, prompt, max_new_tokens=5))
    assert chunked == one.run()[0].tokens


def test_chunked_prefill_sliding_window():
    """Chunk q against a longer cache must not trip local_attention's
    aligned-q/k path (h2o-danube: sliding_window=16, HDP off)."""
    cfg = reduced(get_config("h2o-danube-1.8b"))
    cfg = cfg.replace(hdp=cfg.hdp.replace(enabled=False))
    prompt = _prompts(1, lo=40, hi=41, seed=13)[0]
    eng = Engine(cfg, max_batch=2, max_len=128, prefill_buckets=(32,))
    eng.submit(Request(0, prompt, max_new_tokens=4))
    chunked = eng.run()[0].tokens
    one = Engine(cfg, params=eng.params, max_batch=2, max_len=128,
                 prefill_buckets=(64,))
    one.submit(Request(0, prompt, max_new_tokens=4))
    assert chunked == one.run()[0].tokens


# ------------------------------------------------------------ kernel route
@pytest.mark.slow  # interpret-mode kernel per layer per step
@pytest.mark.parametrize("arch", [
    "qwen2-1.5b",
    "h2o-danube-1.8b",  # sliding window: pallas must fall back to xla
])
def test_pallas_attn_backend_matches_xla(arch):
    cfg = reduced(get_config(arch))
    cfg = cfg.replace(hdp=cfg.hdp.replace(calib="none"))
    prompts = _prompts(2, seed=11)
    eng, xla = _serve(cfg, None, prompts, max_new=4)
    _, pallas = _serve(cfg, eng.params, prompts, max_new=4,
                       attn=AttnSpec(backend="pallas"))
    assert xla == pallas
