"""Serving engine: continuous batching correctness.

The load-bearing test is batched-vs-solo equivalence: every request
generated inside a shared continuously-batched engine must produce the
same tokens as the same request served alone — this pins per-slot
positions, slot cache isolation, and the bucket-padded prefill resume.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.serving import Engine, Request
from repro.serving.kv_cache import kv_read_bytes_per_step


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _dense(cfg):
    return cfg if cfg.hdp is None else cfg.replace(
        hdp=cfg.hdp.replace(enabled=False))


@pytest.mark.parametrize("arch", [
    "qwen2-1.5b",
    pytest.param("rwkv6-3b", marks=pytest.mark.slow),
    pytest.param("zamba2-7b", marks=pytest.mark.slow),
])
def test_batched_equals_solo(arch):
    cfg = _dense(reduced(get_config(arch)))
    import jax
    params = None
    prompts = _prompts(4, seed=3)

    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32))
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=5))
    batched = eng.run()

    for uid, p in enumerate(prompts):
        solo = Engine(cfg, params=params, max_batch=1, max_len=64,
                      prefill_buckets=(16, 32))
        solo.submit(Request(99, p, max_new_tokens=5))
        ref = solo.run()[99].tokens
        assert batched[uid].tokens == ref, \
            f"{arch} req {uid}: batched {batched[uid].tokens} != solo {ref}"


def test_continuous_batching_reuses_slots():
    cfg = _dense(reduced(get_config("qwen2-1.5b")))
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32))
    for uid, p in enumerate(_prompts(5, seed=1)):
        eng.submit(Request(uid, p, max_new_tokens=3))
    res = eng.run()
    assert len(res) == 5
    assert all(len(r.tokens) == 3 for r in res.values())
    # with 2 slots and 5 requests the engine must have recycled slots
    assert eng.metrics["decode_steps"] >= 3


def test_eos_stops_generation():
    cfg = _dense(reduced(get_config("qwen2-1.5b")))
    eng = Engine(cfg, max_batch=1, max_len=64)
    eng.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8))
    ref = eng.run()[0].tokens
    # pick the first token whose value has not occurred before it, so the
    # eos-stop point is unambiguous (random-init models often repeat)
    j = next((i for i in range(1, len(ref)) if ref[i] not in ref[:i]), None)
    if j is None:
        pytest.skip("degenerate generation: all tokens identical")
    eng2 = Engine(cfg, params=eng.params, max_batch=1, max_len=64)
    eng2.submit(Request(0, _prompts(1, seed=2)[0], max_new_tokens=8,
                        eos_id=ref[j]))
    out = eng2.run()[0].tokens
    assert out == ref[:j + 1]


def test_hdp_stats_flow_through_engine():
    cfg = reduced(get_config("granite-8b"))
    assert cfg.hdp is not None
    eng = Engine(cfg, max_batch=2, max_len=64, collect_stats=True)
    for uid, p in enumerate(_prompts(2, seed=5)):
        eng.submit(Request(uid, p, max_new_tokens=3))
    eng.run()
    s = eng.summary()
    assert s["stat_samples"] > 0
    assert 0.0 <= s["block_sparsity"] <= 1.0
    assert s["cache_bytes"] > 0


def test_request_too_long_rejected():
    cfg = _dense(reduced(get_config("qwen2-1.5b")))
    eng = Engine(cfg, max_batch=1, max_len=32)
    with pytest.raises(ValueError):
        eng.submit(Request(0, list(range(1, 30)), max_new_tokens=10))


def test_fum_byte_accounting():
    cfg = reduced(get_config("granite-8b"))
    dense, hdp = kv_read_bytes_per_step(cfg, 1024, 2, 0.5)
    assert hdp < dense
    # int8 scout K always streams: saving is bounded by sparsity
    assert hdp >= int(dense * 0.5 * 0.5)
