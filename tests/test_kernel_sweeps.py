"""Property-based kernel sweeps: random shapes/dtypes vs the jnp oracles.

Deliverable (c): for each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle. Hypothesis drives the shape
space; interpret mode executes the kernel bodies on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow  # randomized interpret-mode sweeps

from repro.core.config import HDPConfig
from repro.core.hdp import hdp_attention
from repro.kernels import ops
from repro.kernels import ref as kref

SETTINGS = dict(max_examples=8, deadline=None)  # kernels are slow in
#                                                 interpret mode; 8 random
#                                                 shapes per property


def _qkv(seed, B, H, S, hd, dtype, scale=1.4):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(  # noqa: E731
        rng.normal(size=(B, H, S, hd)) * scale, dtype)
    return mk(), mk(), mk()


class TestFlashSweep:
    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from([1, 2]), st.sampled_from([1, 2, 4]),
           st.sampled_from([64, 128, 192, 256]),
           st.sampled_from([32, 64, 128]),
           st.booleans())
    @settings(**SETTINGS)
    def test_flash_matches_ref(self, seed, B, H, S, hd, causal):
        q, k, v = _qkv(seed, B, H, S, hd, jnp.float32)
        bq = bk = min(64, S)
        out = ops.flash(q, k, v, causal=causal, block_q=bq, block_k=bk)
        ref = kref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_flash_dtypes(self, dtype):
        q, k, v = _qkv(0, 2, 2, 128, 64, dtype)
        out = ops.flash(q, k, v, causal=True)
        ref = kref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)


class TestHDPPipelineSweep:
    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from([64, 128, 256]),
           st.sampled_from([32, 64]),
           st.floats(-0.8, 0.8),
           st.booleans(), st.booleans())
    @settings(**SETTINGS)
    def test_pipeline_matches_core(self, seed, S, hd, rho, causal, approx):
        """The three-stage kernel pipeline (scout -> head gate -> FUM
        block-sparse attention) equals the batched core-HDP reference for
        TPU-tile block sizes, across shapes/rho/causality/approx."""
        B, H = 1, 2
        q, k, v = _qkv(seed, B, H, S, hd, jnp.float32)
        bq = bk = min(64, S)
        cfg = HDPConfig(rho_b=rho, block_q=bq, block_k=bk, causal=causal,
                        approx=approx, head_pruning=False)
        out, _ = ops.hdp_attention_tpu(q, k, v, cfg)
        ref, _ = hdp_attention(q, k, v, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)

    def test_head_gate_sweep(self):
        q, k, v = _qkv(7, 2, 4, 128, 64, jnp.float32)
        cfg = HDPConfig(rho_b=0.3, block_q=64, block_k=64, causal=True,
                        head_pruning=True, tau_h=1e12,
                        normalize_head_score=False)
        out, st_ = ops.hdp_attention_tpu(q, k, v, cfg, return_stats=True)
        assert float(st_["head_sparsity"]) == 1.0
        np.testing.assert_allclose(np.asarray(out), 0.0)

    def test_scout_theta_matches_blocking(self):
        """Scout-kernel theta == blocking.block_abs_sum of IQ.IK^T."""
        from repro.core import blocking
        from repro.core.quant import calib_scale, quantize_fixed
        from repro.kernels.hdp_scout import hdp_scout
        q, k, _ = _qkv(3, 1, 2, 128, 64, jnp.float32)
        sq = calib_scale(q, 4, "max")
        sk = calib_scale(k, 4, "max")
        iq = jnp.trunc(quantize_fixed(q * sq))
        ik = jnp.trunc(quantize_fixed(k * sk))
        theta, keep, theta_head = hdp_scout(iq, ik, rho_b=0.4, block_q=64,
                                            block_k=64, causal=True,
                                            interpret=True)
        s_int = jnp.einsum("bhqd,bhkd->bhqk", iq, ik)
        mask = blocking.causal_element_mask(128, 128)
        ref = blocking.block_abs_sum(jnp.where(mask, s_int, 0.0), 64, 64)
        np.testing.assert_allclose(np.asarray(theta), np.asarray(ref),
                                   rtol=1e-5, atol=1e-3)
