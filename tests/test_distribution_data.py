"""Distribution rules + data pipeline units."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.data.pipeline import (DataConfig, Prefetcher, host_slice,
                                 make_source)
from repro.distribution import sharding as shd
from repro.distribution.collectives import maybe_compress


def _mesh():
    # 1 real device: a (1, 1) mesh exercises the rule resolution logic
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class TestSpecFor:
    def test_dedup_first_wins(self):
        """MoE (experts, embed, mlp): experts and mlp both map to model;
        the first dim keeps it (pure EP), later dims drop it."""
        mesh = _mesh()
        spec = shd.spec_for(("experts", "embed", "mlp"), (64, 128, 256),
                            mesh, shd.RULES_TP)
        assert spec == P("model", None, None)

    def test_non_divisible_dropped(self):
        mesh = _mesh()
        rules = dict(shd.RULES_TP)
        spec = shd.spec_for(("vocab",), (7,), mesh, rules)  # 7 % 1 == 0
        assert spec == P("model")
        # fake a bigger axis via rules onto a missing mesh axis
        spec2 = shd.spec_for(("heads",), (6,), mesh,
                             dict(rules, heads="nope"))
        assert spec2 == P(None)

    def test_missing_axis_is_none(self):
        mesh = _mesh()  # no "pod" axis
        spec = shd.spec_for(("batch", None), (8, 4), mesh, shd.RULES_TP)
        assert spec == P("data", None)  # ("pod","data") -> present subset

    def test_zero1_adds_data_axis(self):
        mesh = _mesh()
        spec = shd.zero1_spec(("embed", "mlp"), (128, 256), mesh,
                              shd.RULES_TP)
        parts = list(spec) + [None] * (2 - len(spec))
        assert any(p is not None and "data" in (
            p if isinstance(p, tuple) else (p,)) for p in parts)

    def test_shard_activation_noop_outside_ctx(self):
        x = jnp.ones((4, 4))
        y = shd.shard_activation(x, "batch", None)
        assert y is x


class TestGradCompression:
    def test_bf16_compression_rounds_backward(self):
        def loss(p):
            q = maybe_compress(p, "bf16")
            return (q["w"] * 1.2345678).sum()

        p = {"w": jnp.full((8,), 1.0, jnp.float32)}
        g = jax.grad(loss)(p)["w"]
        expect = np.asarray(jnp.asarray(1.2345678, jnp.bfloat16),
                            np.float32)
        np.testing.assert_allclose(np.asarray(g), expect, rtol=0, atol=0)

    def test_none_is_identity(self):
        p = {"w": jnp.ones((4,))}
        assert maybe_compress(p, "none")["w"] is p["w"]


class TestDataPipeline:
    def test_deterministic_across_restart(self):
        cfg = DataConfig(128, 32, 8, seed=5)
        a = make_source(cfg).batch_at(17)
        b = make_source(cfg).batch_at(17)   # fresh instance == restart
        np.testing.assert_array_equal(a, b)

    def test_different_steps_differ(self):
        src = make_source(DataConfig(128, 32, 8, seed=5))
        assert not np.array_equal(src.batch_at(1), src.batch_at(2))

    def test_host_slices_partition(self):
        slices = [host_slice(10, pi, 3) for pi in range(3)]
        rows = sorted(i for s in slices for i in range(s.start, s.stop))
        assert rows == list(range(10))

    def test_prefetcher_ordered_and_sliced(self):
        cfg = DataConfig(64, 16, 6, seed=1)
        src = make_source(cfg)
        with Prefetcher(src, start_step=4, sl=slice(0, 3)) as pf:
            b0 = next(pf)
            b1 = next(pf)
        np.testing.assert_array_equal(b0["tokens"], src.batch_at(4)[:3])
        np.testing.assert_array_equal(b1["tokens"], src.batch_at(5)[:3])

    def test_memorize_cycles(self):
        src = make_source(DataConfig(64, 16, 4, seed=2, kind="memorize"))
        a = src.batch_at(0)
        b = src.batch_at(4)  # 4 batches x 4 rows = one full 16-row cycle
        np.testing.assert_array_equal(a, b)

    def test_synthetic_has_bigram_structure(self):
        """Planted bigrams: successor prediction beats chance by a wide
        margin — the signal that makes trained-attention benchmarks real."""
        cfg = DataConfig(128, 64, 16, seed=9, bigram_rate=0.5)
        src = make_source(cfg)
        toks = src.batch_at(0)
        succ = src._bigram[toks[:, :-1]]
        hit = (toks[:, 1:] == succ).mean()
        assert hit > 0.3   # ~bigram_rate, >> 1/128 chance
