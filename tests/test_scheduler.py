"""Stream scheduler: continuous-batching admission correctness.

The load-bearing invariant mirrors test_serving's: scheduling reorders
*admission* only, never per-slot compute — every request served through
the stream scheduler must produce tokens byte-identical to the static
engine and to running it alone. On top of that these tests pin the
scheduler's own contracts: token-budget deferral (requests the pool
cannot hold wait instead of crashing admission), prefix-hit-first
ordering, mid-run slot recycling with clean allocator refcounts,
chunked prefill interleaved with live decode, the stall watchdog, and
the seeded traffic generator's determinism.
"""
from __future__ import annotations

import numpy as np
import pytest

from benchmarks import traffic
from repro.configs import get_config
from repro.configs.base import reduced
from repro.serving import (Engine, Request, SchedulerConfig, WatchdogError)


def _prompts(n, lo=4, hi=24, seed=0, vocab=250):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _dense(cfg):
    return cfg if cfg.hdp is None else cfg.replace(
        hdp=cfg.hdp.replace(enabled=False))


def _qwen():
    return _dense(reduced(get_config("qwen2-1.5b")))


def test_stream_equals_static_and_recycles():
    cfg = _qwen()
    prompts = _prompts(6, seed=3)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True)
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=5))
    stream = eng.run()

    static = Engine(cfg, params=params, max_batch=2, max_len=64,
                    prefill_buckets=(16, 32))
    for uid, p in enumerate(prompts):
        static.submit(Request(uid, p, max_new_tokens=5))
    ref = static.run()
    assert all(stream[u].tokens == ref[u].tokens for u in ref)
    # 6 requests through 2 slots: admissions past the first wave happened
    # into slots vacated while the engine was already decoding
    assert eng.metrics["sched_recycled"] > 0
    assert eng.metrics["sched_admitted"] == 6
    assert all(stream[u].complete for u in stream)


def test_recycling_keeps_refcounts_clean():
    cfg = _qwen()
    # prefix_cache pinned off: with it on, finished prompts legitimately
    # keep pages referenced from the radix tree, so in_use == 0 would not
    # hold (cache refcount hygiene is test_prefix_cache.py's job)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True, prefix_cache=False)
    for uid, p in enumerate(_prompts(5, seed=1)):
        eng.submit(Request(uid, p, max_new_tokens=3))
    eng.run()
    alloc = eng.pages.allocator
    # every slot retired: no page may keep an owner, the free list must
    # be whole again, and no slot may still hold a table
    assert alloc.in_use == 0
    assert alloc.available == alloc.capacity
    assert all(not eng.pages.slot_pages(s) for s in range(eng.max_batch))
    assert len(eng._free) == eng.max_batch


def test_token_budget_defers_until_pages_free():
    cfg = _qwen()
    prompts = _prompts(3, lo=20, hi=21, seed=9)
    # 3 usable pages (page_size 16): each request needs 2, so only one
    # fits at a time — the second MUST defer, not crash admission (the
    # static engine's group reserve would raise PoolExhausted here)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 num_pages=4, stream_sched=True)
    params = eng.params
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=6))
    out = eng.run()
    assert eng.metrics["sched_deferred"] > 0
    assert all(out[u].complete for u in out)
    for uid, p in enumerate(prompts):
        solo = Engine(cfg, params=params, max_batch=1, max_len=64,
                      prefill_buckets=(16, 32))
        solo.submit(Request(99, p, max_new_tokens=6))
        assert out[uid].tokens == solo.run()[99].tokens


def test_admission_orders_biggest_prefix_hit_first():
    cfg = _qwen()
    rng = np.random.default_rng(17)
    base = rng.integers(1, 250, size=33).tolist()
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32, 48),
                 prefix_cache=True, stream_sched=True)
    eng.submit(Request(0, base, max_new_tokens=3))
    eng.run()   # registers base's first two pages in the radix tree

    cold_a = rng.integers(1, 250, size=12).tolist()
    hot = base[:32] + rng.integers(1, 250, size=6).tolist()
    cold_b = rng.integers(1, 250, size=12).tolist()
    for uid, p in ((1, cold_a), (2, hot), (3, cold_b)):
        eng.submit(Request(uid, p, max_new_tokens=3))
    out = eng.run()
    # the cached-prefix request jumps the FIFO; misses keep their order
    assert eng.sched.admitted_uids == [0, 2, 1, 3]
    assert eng.prefix.hits > 0
    assert all(out[u].complete for u in out)


def test_chunked_prefill_interleaves_with_decode():
    cfg = _qwen()
    rng = np.random.default_rng(7)
    long_p = rng.integers(1, 250, size=80).tolist()
    shorts = _prompts(3, seed=11)
    # horizon/spec pinned to single-token steps: at H=4 (or with draft
    # rounds) the 4-token shorts finish inside one engine step, so no
    # decode is ever live while a chunk advances and the interleaving
    # counter stays 0 — composition with those features is covered by
    # test_everything_on_composition_token_identity
    eng = Engine(cfg, max_batch=2, max_len=128, prefill_buckets=(16, 32),
                 stream_sched=True, decode_horizon=1, spec_decode=False,
                 sched=SchedulerConfig(prefill_chunk_tokens=32))
    params = eng.params
    eng.submit(Request(0, long_p, max_new_tokens=4))
    for uid, p in enumerate(shorts, start=1):
        eng.submit(Request(uid, p, max_new_tokens=4))
    out = eng.run()
    # the long prompt prefilled through per-step slices, some of which
    # ran while other slots were actively decoding
    assert eng.metrics["sched_chunk_tokens"] >= 80
    assert eng.metrics["sched_interleaved_steps"] > 0
    for uid, p in [(0, long_p)] + list(enumerate(shorts, start=1)):
        solo = Engine(cfg, params=params, max_batch=1, max_len=128,
                      prefill_buckets=(16, 32))
        solo.submit(Request(99, p, max_new_tokens=4))
        assert out[uid].tokens == solo.run()[99].tokens, f"req {uid}"


def test_watchdog_sheds_stuck_request():
    cfg = _qwen()
    # 2 usable pages but the request's footprint needs 4: no amount of
    # waiting can ever admit it — the watchdog must shed it as a typed
    # per-request failure instead of killing the serving loop
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 num_pages=3, stream_sched=True,
                 sched=SchedulerConfig(watchdog_steps=5))
    eng.submit(Request(0, _prompts(1, lo=20, hi=21, seed=5)[0],
                       max_new_tokens=30))
    out = eng.run()
    assert out[0].status == "error" and not out[0].complete
    assert "watchdog" in out[0].error
    assert eng.metrics["watchdog_shed"] == 1
    eng.pages.allocator.assert_drained()


def test_watchdog_escalation_zero_raises():
    cfg = _qwen()
    # escalation 0 restores the legacy loop-fatal behaviour
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 num_pages=3, stream_sched=True,
                 sched=SchedulerConfig(watchdog_steps=5,
                                       watchdog_escalation=0))
    eng.submit(Request(0, _prompts(1, lo=20, hi=21, seed=5)[0],
                       max_new_tokens=30))
    with pytest.raises(WatchdogError, match=r"\[0\] pending"):
        eng.run()


def test_serve_generator_streams_in_completion_order():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=True)
    reqs = [Request(uid, p, max_new_tokens=3 + uid % 3)
            for uid, p in enumerate(_prompts(4, seed=13))]
    seen = [r.uid for r in eng.serve(reqs)]
    assert sorted(seen) == [0, 1, 2, 3]
    assert all(eng.results()[u].complete for u in seen)
    s = eng.summary()
    assert s["ttft_s_mean"] > 0 and s["queue_wait_s_mean"] >= 0
    assert s["queue_depth_peak"] >= 1


def test_everything_on_composition_token_identity():
    # horizon + prefix cache + spec decode + stream scheduler, HDP on —
    # the CI interaction leg's contract in one test
    cfg = reduced(get_config("granite-8b"))
    assert cfg.hdp is not None and cfg.hdp.enabled
    kw = dict(max_batch=2, max_len=64, prefill_buckets=(16, 32),
              decode_horizon=4, prefix_cache=True, spec_decode=True)
    eng = Engine(cfg, stream_sched=True, **kw)
    prompts = _prompts(5, seed=21)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=5))
    stream = eng.run()
    static = Engine(cfg, params=eng.params, **kw)
    for uid, p in enumerate(prompts):
        static.submit(Request(uid, p, max_new_tokens=5))
    ref = static.run()
    assert all(stream[u].tokens == ref[u].tokens for u in ref)
    assert eng.metrics["sched_recycled"] > 0


def test_traffic_generator_is_deterministic():
    cfg = traffic.TrafficConfig(n_requests=12, rate=0.4, long_frac=0.25,
                                seed=42)
    a, b = traffic.generate(cfg), traffic.generate(cfg)
    assert [(r.uid, r.arrival_step, r.prompt, r.max_new_tokens)
            for r in a] == \
           [(r.uid, r.arrival_step, r.prompt, r.max_new_tokens)
            for r in b]
    # arrival steps are a non-decreasing Poisson cumsum, uids in order
    assert all(x.arrival_step <= y.arrival_step for x, y in zip(a, a[1:]))
    assert [r.uid for r in a] == list(range(12))
    # a different seed moves the trace
    c = traffic.generate(traffic.TrafficConfig(n_requests=12, rate=0.4,
                                               long_frac=0.25, seed=43))
    assert [r.prompt for r in c] != [r.prompt for r in a]


def test_traffic_burst_and_replay():
    cfg = traffic.TrafficConfig(n_requests=5, arrival="burst",
                                prompt_lo=4, prompt_hi=12, max_new_lo=3,
                                max_new_hi=4, seed=8)
    trace = traffic.generate(cfg)
    assert all(r.arrival_step == 0 for r in trace)
    eng = Engine(_qwen(), max_batch=2, max_len=64,
                 prefill_buckets=(16, 32), stream_sched=True)
    results, steps = traffic.replay(eng, trace, Request)
    assert sorted(results) == [0, 1, 2, 3, 4]
    assert all(results[u].complete for u in results)
    assert steps >= 3   # 5 requests / 2 slots cannot drain in one wave


def test_scheduler_config_validation():
    with pytest.raises(ValueError):
        SchedulerConfig(order="lifo")
    with pytest.raises(ValueError):
        SchedulerConfig(watchdog_steps=0)
    with pytest.raises(ValueError):
        SchedulerConfig(prefill_chunk_tokens=0)
    with pytest.raises(ValueError):
        traffic.TrafficConfig(arrival="weibull")
    with pytest.raises(ValueError):
        traffic.TrafficConfig(arrival="poisson", rate=0.0)
