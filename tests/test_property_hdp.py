"""Hypothesis property tests on HDP's algebraic invariants.

These pin the *identities* the system depends on — quantization algebra,
threshold monotonicity, row balance, softmax exclusion — over arbitrary
inputs, not hand-picked examples.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

pytestmark = pytest.mark.slow  # randomized property sweeps

from repro.core import blocking
from repro.core.config import HDPConfig
from repro.core.quant import calib_scale, int_frac_split, quantize_fixed

SETTINGS = dict(max_examples=40, deadline=None)

floats = st.floats(min_value=-15.0, max_value=15.0,
                   allow_nan=False, allow_infinity=False, width=32)


def arrays(shape):
    return hnp.arrays(np.float32, shape, elements=floats)


class TestQuantProperties:
    @given(arrays((8, 8)))
    @settings(**SETTINGS)
    def test_split_reconstructs_and_bounds(self, x):
        xq = quantize_fixed(jnp.asarray(x))
        i, f = int_frac_split(xq)
        assert np.allclose(np.asarray(i) + np.asarray(f), np.asarray(xq),
                           atol=1e-6)
        assert np.all(np.asarray(i) == np.trunc(np.asarray(i)))
        assert np.all(np.abs(np.asarray(f)) < 1.0)
        # signs agree: trunc-toward-zero keeps F on x's side
        assert np.all(np.asarray(i) * np.asarray(xq) >= 0)

    @given(arrays((6, 6)))
    @settings(**SETTINGS)
    def test_quantize_idempotent_and_error_bound(self, x):
        xq = quantize_fixed(jnp.asarray(x))
        xqq = quantize_fixed(xq)
        assert np.allclose(np.asarray(xq), np.asarray(xqq), atol=0)
        # inside the representable range the error is at most half a step
        step = 2.0 ** -12
        inside = np.abs(x) < 15.9
        err = np.abs(np.asarray(xq) - x)[inside]
        assert np.all(err <= step / 2 + 1e-9)

    @given(arrays((5, 7)), arrays((6, 7)))
    @settings(**SETTINGS)
    def test_three_term_identity(self, x, y):
        """II + IF + FI == (I+F)(I+F) - FF for any quantized tensors."""
        xq = quantize_fixed(jnp.asarray(x))
        yq = quantize_fixed(jnp.asarray(y))
        ix, fx = int_frac_split(xq)
        iy, fy = int_frac_split(yq)
        three = ix @ iy.T + ix @ fy.T + fx @ iy.T
        ident = xq @ yq.T - fx @ fy.T
        assert np.allclose(np.asarray(three), np.asarray(ident),
                           rtol=1e-4, atol=1e-3)

    @given(arrays((4, 16)), st.sampled_from(["max", "rms"]))
    @settings(**SETTINGS)
    def test_calibration_in_range(self, x, mode):
        s = calib_scale(jnp.asarray(x), 4, mode)
        assert float(s) > 0
        if mode == "max":
            scaled = np.abs(x * float(s))
            assert scaled.max() <= 16.0 + 1e-4


class TestThresholdProperties:
    @given(hnp.arrays(np.float32, (3, 4, 8),
                      elements=st.floats(0, 100, width=32)),
           st.floats(-0.95, 0.95))
    @settings(**SETTINGS)
    def test_threshold_between_min_and_max(self, theta, rho):
        t = jnp.asarray(theta)
        thr = blocking.row_threshold(t, rho)
        lo = theta.min(-1, keepdims=True) - 1e-4
        hi = theta.max(-1, keepdims=True) + 1e-4
        assert np.all(np.asarray(thr) >= lo)
        assert np.all(np.asarray(thr) <= hi)

    @given(hnp.arrays(np.float32, (2, 5, 6),
                      elements=st.floats(0, 50, width=32)))
    @settings(**SETTINGS)
    def test_threshold_monotone_in_rho(self, theta):
        t = jnp.asarray(theta)
        rhos = (-0.8, -0.4, 0.0, 0.4, 0.8)
        ths = [np.asarray(blocking.row_threshold(t, r)) for r in rhos]
        for a, b in zip(ths, ths[1:]):
            assert np.all(b >= a - 1e-4)

    @given(hnp.arrays(np.float32, (3, 6, 8),
                      elements=st.floats(0, 50, width=32)),
           st.floats(-0.9, 0.9))
    @settings(**SETTINGS)
    def test_row_balance_every_row_keeps_one(self, theta, rho):
        """Row-balanced sparsity: the max block of every row survives
        (Theta <= max by construction) — no row is fully pruned. A one-ulp
        tolerance covers float32 rounding when a row is constant (then
        Theta == max up to rounding)."""
        t = jnp.asarray(theta)
        thr = np.asarray(blocking.row_threshold(t, rho))
        tol = 1e-4 + 1e-5 * np.abs(thr)
        keep = theta >= (thr - tol)
        assert bool(np.all(keep.any(axis=-1)))


class TestSoftmaxProperties:
    @given(hnp.arrays(np.float32, (4, 8), elements=floats),
           hnp.arrays(np.bool_, (4, 8), elements=st.booleans()))
    @settings(**SETTINGS)
    def test_masked_softmax_partition(self, s, keep):
        p = np.asarray(blocking.masked_softmax(jnp.asarray(s),
                                               jnp.asarray(keep)))
        # excluded entries carry zero probability
        assert np.all(p[~keep] == 0)
        sums = p.sum(-1)
        has = keep.any(-1)
        assert np.allclose(sums[has], 1.0, atol=1e-5)
        assert np.allclose(sums[~has], 0.0, atol=1e-6)

    @given(hnp.arrays(np.float32, (3, 16),
                      elements=st.floats(-30, 0, width=32)))
    @settings(**SETTINGS)
    def test_poly_exp_relative_error(self, x):
        e = np.asarray(blocking.poly_exp(jnp.asarray(x)))
        ref = np.exp(x)
        assert np.all(np.abs(e - ref) <= 0.04 * ref + 1e-6)


class TestNetSparsityProperties:
    @given(hnp.arrays(np.bool_, (2, 3, 4, 4), elements=st.booleans()),
           hnp.arrays(np.bool_, (2, 3), elements=st.booleans()))
    @settings(**SETTINGS)
    def test_net_sparsity_bounds(self, keep, heads):
        bsp, hsp, net = blocking.net_sparsity(
            jnp.asarray(keep), jnp.asarray(heads)[..., None, None])
        for v in (bsp, hsp, net):
            assert -1e-6 <= float(v) <= 1.0 + 1e-6
        # net >= head sparsity (a pruned head prunes all its blocks)
        assert float(net) >= float(hsp) - 1e-5


class TestEndToEndProperties:
    @given(st.integers(0, 2 ** 31 - 1), st.floats(-0.9, 0.9),
           st.booleans())
    @settings(max_examples=10, deadline=None)
    def test_hdp_attention_finite_and_sane(self, seed, rho, causal):
        import jax
        from repro.core.hdp import hdp_attention
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
        cfg = HDPConfig(rho_b=rho, causal=causal, tau_h=0.0,
                        normalize_head_score=True)
        out, st_ = hdp_attention(q, k, v, cfg)
        assert bool(jnp.isfinite(out).all())
        # output is a convex combination of V rows per kept head: bounded
        assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
        assert 0.0 <= float(st_.net_sparsity) <= 1.0
