"""Refcounted page allocator + radix-tree prefix caching.

Load-bearing guarantees pinned here:

* allocator refcount invariants hold under interleaved submit/finish
  with shared prompts: every page's refcount equals its slot owners
  plus its prefix-cache residency, and free + in-use conserves capacity;
* a prefix-cache hit is token-identical to cold prefill, against both
  the cold paged engine and the dense-layout engine (full hits, suffix
  hits and chunked long-prompt hits);
* extending a shared prefix's tail copies the page first (COW): the
  donor's cached page bytes never change, and the write floor threaded
  into the decode jit fences shared pages from the K/V write;
* under pool pressure, LRU unreferenced cached pages are evicted —
  serving keeps completing instead of hard-failing;
* NaN poison lands on *true free only*: a page still shared by any
  owner is never poisoned (last-unref semantics);
* batched prefill donates the serving cache (fused prefill+scatter jit)
  and take()/put() guard stale handles;
* parked slots are masked out of the batchwise sparsity means.

Identity tests run ``head_pruning=False``: HDP's early head gate is a
whole-forward decision (theta_head sums over every position the call
sees), so prefill hidden states are only prefix-causal while the gate
decisions agree — the same per-forward-call caveat chunked prefill
documents. The stock-config identity on a realistic shared-prefix
workload is asserted by ``benchmarks.run --only serving_prefix``.
"""
from __future__ import annotations

from collections import Counter

import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec
from repro.configs import get_config
from repro.configs.base import reduced
from repro.serving import Engine, PageAllocator, RadixPrefixCache, Request
from repro.serving.kv_cache import DonatedCacheError, PagedKVCache


def _qwen(head_pruning=False):
    cfg = reduced(get_config("qwen2-1.5b"))
    return cfg.replace(hdp=cfg.hdp.replace(calib="none",
                                           head_pruning=head_pruning))


def _shared_prompts(n_tail=3, prefix_len=20, seed=0, vocab=250):
    """Prompts sharing a page-aligned prefix + aligned sub-prefix prompts."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, size=prefix_len).tolist()
    prompts = [shared + rng.integers(1, vocab, size=5 + i).tolist()
               for i in range(n_tail)]
    prompts.append(shared[:6])     # full-page-aligned prefix -> full hit
    prompts.append(shared[:12])    # deeper full hit
    return prompts


def _serve(cfg, params, prompts, *, prefix, max_new=4, layout=None, **kw):
    if layout is not None:
        kw["attn"] = AttnSpec(layout=layout)
    eng = Engine(cfg, params=params, max_batch=2, max_len=64,
                 prefill_buckets=(16, 32), prefix_cache=prefix, **kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=max_new))
    res = eng.run()
    return eng, {u: r.tokens for u, r in res.items()}


# ------------------------------------------------------------- allocator unit
def test_allocator_refcount_lifecycle():
    al = PageAllocator(8, reserved=1)
    assert al.capacity == 7 and al.available == 7 and al.in_use == 0
    a = al.alloc(3)
    assert 0 not in a and al.in_use == 3
    al.ref([a[0]])                       # second owner
    assert al.refcount(a[0]) == 2
    freed = al.unref(a)                  # drop first owner of all three
    assert freed == a[1:] and al.in_use == 1
    assert al.refcount(a[0]) == 1
    assert al.unref([a[0]]) == [a[0]]    # last unref -> true free
    assert al.available == 7 and al.in_use == 0
    with pytest.raises(ValueError):
        al.unref([a[0]])                 # double free
    with pytest.raises(ValueError):
        al.ref([a[0]])                   # ref of a free page
    with pytest.raises(RuntimeError):
        al.alloc(8)                      # beyond capacity
    # freed pages return to the FRONT: deterministic hot reuse
    assert al.alloc(2) == a[:2]


def test_radix_match_insert_lru():
    al = PageAllocator(16, reserved=1)
    tree = RadixPrefixCache(al, page_size=2)
    toks = [1, 2, 3, 4, 5, 6]
    pages = al.alloc(3)
    assert tree.insert(toks, pages) == 3
    assert tree.cached_pages == 3
    assert [al.refcount(p) for p in pages] == [2, 2, 2]
    al.unref(pages)                      # original owner retires
    m = tree.match([1, 2, 3, 4, 9, 9])   # partial: two chunks
    assert m == pages[:2] and al.refcount(pages[0]) == 2
    al.unref(m)
    assert tree.match([7, 7]) == [] and tree.misses == 1
    # LRU: matched path was bumped; the unmatched tail page evicts first
    assert tree.evict(1) == 1
    assert tree.cached_pages == 2 and al.refcount(pages[2]) == 0
    # pinned leaves (slot-referenced) are skipped
    m = tree.match([1, 2, 3, 4])
    assert tree.evict(4) == 0            # both remaining pages pinned
    al.unref(m)
    assert tree.evict(4) == 2 and tree.cached_pages == 0
    assert al.in_use == 0


# ------------------------------------------------------------ token identity
def test_prefix_hit_matches_cold_and_dense():
    """Full hits, suffix hits and sub-prefix hits are token-identical to
    a cold paged engine and to the dense-layout engine."""
    cfg = _qwen()
    prompts = _shared_prompts()
    # the dense comparison needs the fp32 pool (dense caches never
    # round-trip K/V through the int8 store); the hit-vs-cold identity
    # under the default int8 pool is pinned in test_kv_quant.py
    fp32 = AttnSpec(kv_dtype="fp32")
    e1, cold = _serve(cfg, None, prompts, prefix=False, attn=fp32)
    e2, hot = _serve(cfg, e1.params, prompts, prefix=True, attn=fp32)
    _, dense = _serve(cfg, e1.params, prompts, prefix=None, layout="dense")
    assert hot == cold, f"hit tokens diverged: {hot} != {cold}"
    assert dense == cold
    s = e2.summary()
    assert s["prefix_hits"] > 0 and s["prefix_hit_tokens"] > 0
    assert s["cow_copies"] >= 1          # the two full hits COW their tail
    assert s["pages_cached"] > 0


def test_long_prompt_hit_matches_cold():
    """A >bucket prompt sharing a long prefix goes through the deferred
    (late-matched) chunked path and must match the cold chunked path."""
    cfg = _qwen()
    rng = np.random.default_rng(7)
    shared = rng.integers(1, 250, size=40).tolist()   # > largest bucket (32)
    prompts = [shared + rng.integers(1, 250, size=4 + i).tolist()
               for i in range(2)]
    e1, cold = _serve(cfg, None, prompts, prefix=False)
    e2, hot = _serve(cfg, e1.params, prompts, prefix=True)
    assert hot == cold
    # second long prompt was admitted in the same wave yet still hit the
    # pages the first one registered moments earlier (deferred matching)
    assert e2.summary()["prefix_hits"] >= 1


# ------------------------------------------------------- refcount invariants
def _check_refcounts(eng):
    """Every page's refcount == its slot owners + prefix-cache residency."""
    owners = Counter()
    for pages in eng.pages._slot_pages.values():
        owners.update(pages)
    cached = set()
    stack = list(eng.prefix._root.children.values())
    while stack:
        n = stack.pop()
        cached.add(n.page)
        stack.extend(n.children.values())
    al = eng.pages.allocator
    for p in range(1, eng.pages.num_pages):
        assert al.refcount(p) == owners[p] + (p in cached), \
            f"page {p}: refs {al.refcount(p)} != owners {owners[p]} " \
            f"+ cached {p in cached}"
    assert al.available + al.in_use == al.capacity


def test_refcounts_under_interleaved_submit_finish():
    cfg = _qwen()
    prompts = _shared_prompts(n_tail=4, seed=3)
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 prefix_cache=True)
    for uid, p in enumerate(prompts):
        # staggered budgets force slots to finish and refill mid-flight
        eng.submit(Request(uid, p, max_new_tokens=3 + (uid % 3)))
    steps = 0
    while (eng._queue or eng._active) and steps < 200:
        eng.step()
        _check_refcounts(eng)
        steps += 1
    assert not eng._queue and not eng._active
    # at drain: only the prefix cache holds pages
    assert eng.pages.pages_in_use == eng.prefix.cached_pages


# ------------------------------------------------------------------ COW
def test_cow_on_shared_tail_extension():
    """A full-prefix hit extends the shared chain's tail: the last shared
    page must be COW'd so the donor's cached page bytes never change."""
    cfg = _qwen()
    rng = np.random.default_rng(11)
    donor = rng.integers(1, 250, size=13).tolist()
    eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16, 32),
                 prefix_cache=True)
    eng.submit(Request(0, donor, max_new_tokens=3))
    eng.run()
    # donor cached (13-1)//2 = 6 pages covering tokens [0, 12)
    assert eng.prefix.cached_pages == 6
    matched = eng.prefix.match(donor[:12])
    tail_page = matched[-1]
    eng.pages.allocator.unref(matched)   # match refs for the caller
    before = np.asarray(eng.pages.cache["k_pages"][:, tail_page])

    eng.submit(Request(1, donor[:12], max_new_tokens=3))   # full hit
    res = eng.run()
    s = eng.summary()
    assert s["cow_copies"] == 1
    after = np.asarray(eng.pages.cache["k_pages"][:, tail_page])
    np.testing.assert_array_equal(before, after)

    solo = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                  prefill_buckets=(16, 32), prefix_cache=False)
    solo.submit(Request(9, donor[:12], max_new_tokens=3))
    assert res[1].tokens == solo.run()[9].tokens


def test_write_floor_fences_shared_pages():
    """Even with COW bypassed, the write floor threaded into the decode
    jit redirects sub-floor writes to the scratch page (defence in depth
    for the shared-page immutability contract)."""
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=32, prefix_cache=False)
    eng.submit(Request(0, [5, 6, 7, 8], max_new_tokens=2))
    eng._admit()
    slot_pages = eng.pages.slot_pages(0)
    # raise the floor above every page: all decode writes must divert
    eng._floor_dev = eng._floor_dev.at[0].set(len(slot_pages))
    before = {p: np.asarray(eng.pages.cache["k_pages"][:, p])
              for p in slot_pages}
    eng.step()
    for p, b in before.items():
        np.testing.assert_array_equal(
            b, np.asarray(eng.pages.cache["k_pages"][:, p]),
            err_msg=f"page {p} written below the floor")


# ------------------------------------------------------------------ eviction
def test_eviction_under_pressure():
    cfg = _qwen()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 250, size=20).tolist() for _ in range(3)]
    # pool sized so the 2nd/3rd distinct prompts only fit by evicting the
    # previous prompt's cached pages
    eng = Engine(cfg, max_batch=1, max_len=32, num_pages=1 + 14,
                 prefix_cache=True)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=4))
    res = eng.run()
    s = eng.summary()
    assert s["prefix_evictions"] > 0, "pool pressure produced no eviction"
    assert all(len(r.tokens) == 4 for r in res.values())
    solo = Engine(cfg, params=eng.params, max_batch=1, max_len=32,
                  prefix_cache=False)
    solo.submit(Request(9, prompts[-1], max_new_tokens=4))
    assert res[2].tokens == solo.run()[9].tokens


def test_hit_unwinds_cleanly_on_pool_exhaustion():
    """A hit that cannot reserve its fresh pages must release its match
    refs and go back on the queue — refcounts stay balanced and the
    engine keeps serving after the failure surfaces."""
    cfg = _qwen()
    rng = np.random.default_rng(13)
    donor = rng.integers(1, 250, size=16).tolist()
    # stream_sched pinned off: this drives the static _admit() path,
    # whose contract is to *raise* on exhaustion (the scheduler defers
    # instead — that side is covered by test_scheduler.py)
    eng = Engine(cfg, max_batch=2, max_len=32, num_pages=1 + 22,
                 prefix_cache=True, stream_sched=False)
    eng.submit(Request(0, donor, max_new_tokens=2))
    eng.run()                              # caches (16-1)//2 = 7 pages
    eng.submit(Request(1, rng.integers(1, 250, size=20).tolist(),
                       max_new_tokens=4))
    eng._admit()                           # live blocker slot: 12 pages
    # suffix hit needing 8 fresh pages with only 3 free and the donor's
    # cached pages pinned by the match itself -> reservation must fail
    eng.submit(Request(2, donor + rng.integers(1, 250, size=10).tolist(),
                       max_new_tokens=4))
    with pytest.raises(RuntimeError, match="exhausted"):
        eng.step()
    assert len(eng._queue) == 1            # hit requeued, not dropped
    _check_refcounts(eng)                  # match refs released
    eng._queue.clear()                     # drop the unserviceable request
    res = eng.run()
    assert len(res[1].tokens) == 4         # blocker still completes


def test_hit_falls_back_to_cold_when_own_match_pins_pool():
    """A full hit whose own match refs pin every evictable page must not
    livelock: it releases the refs and serves cold, which may evict the
    very pages the hit wanted to share."""
    cfg = _qwen()
    rng = np.random.default_rng(17)
    donor = rng.integers(1, 250, size=16).tolist()
    eng = Engine(cfg, max_batch=2, max_len=32, num_pages=1 + 20,
                 prefix_cache=True)
    eng.submit(Request(0, donor, max_new_tokens=2))
    eng.run()                              # caches 7 pages
    eng.submit(Request(1, rng.integers(1, 250, size=20).tolist(),
                       max_new_tokens=4))
    eng._admit()                           # hog slot: 12 pages, 1 free
    # full hit needs 2 fresh (COW + generation) with 1 free and all
    # cached pages pinned by this very match -> must fall back to cold
    eng.submit(Request(2, donor[:14], max_new_tokens=2))
    res = eng.run()
    assert len(res[2].tokens) == 2 and len(res[1].tokens) == 4
    assert eng.summary()["prefix_evictions"] > 0   # cold path evicted them
    solo = Engine(cfg, params=eng.params, max_batch=1, max_len=32,
                  prefix_cache=False)
    solo.submit(Request(9, donor[:14], max_new_tokens=2))
    assert res[2].tokens == solo.run()[9].tokens


def test_match_alignment_trims_before_counting():
    al = PageAllocator(16, reserved=1)
    tree = RadixPrefixCache(al, page_size=2)
    pages = al.alloc(3)
    tree.insert([1, 2, 3, 4, 5, 6], pages)
    # align=2 pages: a 3-page walk trims to 2; a 1-page walk trims to 0
    # and must count as a miss with no refs taken
    m = tree.match([1, 2, 3, 4, 5, 6], align=2)
    assert m == pages[:2] and tree.hits == 1 and tree.hit_tokens == 4
    al.unref(m)
    assert tree.match([1, 2, 9], align=2) == []
    assert tree.misses == 1
    assert al.refcount(pages[0]) == 2      # only the insert + cache refs


def test_pool_exhaustion_still_raises_when_nothing_evictable():
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=32, num_pages=1 + 12,
                 prefix_cache=True, stream_sched=False)
    eng.submit(Request(0, list(range(1, 21)), max_new_tokens=4))
    eng._admit()                          # slot 0 holds 12 pages, 0 free
    eng.submit(Request(1, list(range(30, 50)), max_new_tokens=4))
    with pytest.raises(RuntimeError, match="exhausted"):
        eng._admit()                      # nothing evictable: all slot-owned


# ------------------------------------------------------------- poison / free
def test_nan_poison_on_last_unref_only():
    # poison_view() is the dtype-independent face of the poison channel:
    # NaN K for the fp32 pool, NaN page scale for the quantized default
    cfg = _qwen()
    pool = PagedKVCache(cfg, batch=2, max_len=8, poison_freed=True)
    pages = pool.alloc(0, 6)              # 3 pages, refcount 1 each
    idx = jnp.asarray(pages)
    finite = jnp.ones_like(pool.cache["k_pages"][:, idx])
    pool.cache = {**pool.cache, "k_pages": pool.cache["k_pages"]
                  .at[:, idx].set(finite)}
    pool.allocator.ref([pages[0]])        # pages[0] shared by a 2nd owner
    pool.free(0)
    poisoned = np.asarray(pool.poison_view())
    assert not poisoned[:, pages[0]].any(), \
        "shared page poisoned before its last unref"
    assert poisoned[:, pages[1]].all() and poisoned[:, pages[2]].all()
    pool.allocator.unref([pages[0]])      # last owner gone -> poison
    assert np.asarray(pool.poison_view())[:, pages[0]].all()


# ------------------------------------------------- batched prefill donation
def test_batched_prefill_donates_pool():
    """The fused prefill+scatter jit aliases the page pool in place: the
    pre-admit pool buffer is deleted, and a stale take() guard trips."""
    cfg = _qwen()
    # static path pinned: submit() must land in _queue for the direct
    # _admit() call below to exercise the fused group prefill
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 stream_sched=False)
    for uid in range(2):
        eng.submit(Request(uid, [1 + uid, 2, 3, 4, 5], max_new_tokens=2))
    old = eng.pages.cache
    eng._admit()
    assert all(old[k].is_deleted() for k in old), \
        "batched prefill allocated a second page pool"
    # take()/put() guard across the donating admission path
    held = eng.pages.take()
    with pytest.raises(DonatedCacheError):
        eng.pages.cache
    eng.pages.put(held)
    eng.run()

    dense = Engine(cfg, params=eng.params, max_batch=2, max_len=64,
                   prefill_buckets=(16, 32), attn=AttnSpec(layout="dense"),
                   stream_sched=False)
    for uid in range(2):
        dense.submit(Request(uid, [1 + uid, 2, 3, 4, 5], max_new_tokens=2))
    old_k = dense.slots.cache["k"]
    dense._admit()
    assert old_k.is_deleted(), \
        "dense batched prefill allocated a second slot cache"
    dense.run()


# ------------------------------------------------------- per-slot stat mask
def test_parked_slots_masked_from_stats():
    from repro.attention.stats import AttnStats
    cfg = _qwen()
    eng = Engine(cfg, max_batch=2, max_len=32, collect_stats=True)
    stats = AttnStats(block_sparsity=jnp.asarray([[0.5, 1.0], [0.5, 1.0]]),
                      head_sparsity=jnp.asarray([[0.25, 1.0], [0.25, 1.0]]))
    eng._record_stats(stats, mask=np.array([True, False]))
    assert eng.metrics["block_sparsity"] == pytest.approx(0.5)
    assert eng.metrics["head_sparsity"] == pytest.approx(0.25)
    # an all-parked step records nothing
    eng._record_stats(stats, mask=np.array([False, False]))
    assert eng.metrics["stat_samples"] == 1


def test_engine_decode_stats_are_per_slot():
    """End-to-end: with one slot parked mid-batch, recorded sparsity uses
    only live slots (identical to serving the request alone)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    cfg = cfg.replace(hdp=cfg.hdp.replace(calib="none"))
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, 250, size=10).tolist()

    solo = Engine(cfg, max_batch=1, max_len=64, collect_stats=True)
    solo.submit(Request(0, prompt, max_new_tokens=4))
    solo.run()

    # same request in a 2-slot engine: slot 1 never occupied (parked)
    duo = Engine(cfg, params=solo.params, max_batch=2, max_len=64,
                 collect_stats=True)
    duo.submit(Request(0, prompt, max_new_tokens=4))
    duo.run()
    assert duo.summary()["block_sparsity"] == \
        pytest.approx(solo.summary()["block_sparsity"], abs=1e-6)
    assert duo.summary()["page_sparsity"] == \
        pytest.approx(solo.summary()["page_sparsity"], abs=1e-6)


# ---------------------------------------------------------------- guardrails
def test_prefix_cache_requires_paged_layout():
    cfg = _qwen()
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, max_batch=1, max_len=32, prefix_cache=True,
               attn=AttnSpec(layout="dense"))


def test_prefix_cache_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_PREFIX_CACHE", "1")
    cfg = _qwen()
    assert Engine(cfg, max_batch=1, max_len=32).prefix is not None
    # explicit kwarg wins over the env; dense degrades silently
    assert Engine(cfg, max_batch=1, max_len=32,
                  prefix_cache=False).prefix is None
    assert Engine(cfg, max_batch=1, max_len=32,
                  attn=AttnSpec(layout="dense")).prefix is None
