"""Checkpointing (atomic, resume, elastic) and fault-tolerance units."""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import fault


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        "opt": {"step": jnp.asarray(3, jnp.int32),
                "m": {"w": jnp.zeros((4, 8)), "b": jnp.ones((8,))}},
    }


class TestCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        s = _state()
        ckpt.save_checkpoint(str(tmp_path), 10, s, meta={"loss": 1.5})
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
        out, step, meta = ckpt.load_checkpoint(str(tmp_path), like)
        assert step == 10 and meta["loss"] == 1.5
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_retention(self, tmp_path):
        for step in (1, 2, 3, 4):
            ckpt.save_checkpoint(str(tmp_path), step, _state(step), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert len(kept) == 2

    def test_structure_mismatch_rejected(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 1, _state())
        bad = {"params": {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}}
        with pytest.raises(ValueError):
            ckpt.load_checkpoint(str(tmp_path), bad)

    def test_shape_mismatch_rejected(self, tmp_path):
        s = _state()
        ckpt.save_checkpoint(str(tmp_path), 1, s)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
        like["params"]["w"] = jax.ShapeDtypeStruct((5, 8), jnp.float32)
        with pytest.raises(ValueError):
            ckpt.load_checkpoint(str(tmp_path), like)

    def test_elastic_reshard_onto_shardings(self, tmp_path):
        """Leaves stored as full logical arrays restore under any sharding
        — here a 1-device mesh stands in for a resized cluster."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        s = _state()
        ckpt.save_checkpoint(str(tmp_path), 2, s)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), s)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
        out, step, _ = ckpt.load_checkpoint(str(tmp_path), like,
                                            shardings=sh)
        assert step == 2
        w = jax.tree.leaves(out)[0]
        assert w.sharding.mesh.shape == {"data": 1, "model": 1}

    def test_manager_restore_or_init(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), interval=2, keep=2)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _state())
        st0, step0, _ = mgr.restore_or(like, _state)
        assert step0 == 0
        assert mgr.maybe_save(1, st0) is None      # not on interval
        assert mgr.maybe_save(2, st0) is not None  # on interval
        _, step1, _ = mgr.restore_or(like, _state)
        assert step1 == 2

    def test_atomic_no_partial_dirs(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 5, _state())
        entries = os.listdir(tmp_path)
        assert not [e for e in entries if ".tmp" in e]
        man = json.load(open(tmp_path / "step_00000005" / "manifest.json"))
        assert man["n_leaves"] == len(jax.tree.leaves(_state()))


class TestFault:
    def test_step_timer_flags_straggler(self):
        t = fault.StepTimer(window=20, threshold=2.0, warmup=0)
        for i in range(10):
            t.start()
            time.sleep(0.002)
            t.stop(i)
        t.start()
        time.sleep(0.05)  # 25x median
        t.stop(10)
        assert len(t.events) == 1
        assert t.events[0].slowdown > 2.0
        assert t.summary()["stragglers"] == 1

    def test_watchdog_fires_and_beats(self):
        fired = threading.Event()
        with fault.Watchdog(0.15, fired.set, poll_s=0.02) as wd:
            for _ in range(5):   # heartbeats keep it quiet
                time.sleep(0.05)
                wd.beat()
            assert not wd.fired
            time.sleep(0.3)      # silence -> fire
        assert fired.is_set() and wd.fired

    def test_retry_recovers_with_hook(self):
        calls = {"n": 0, "restored": 0}

        def flaky(x):
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("collective timeout")
            return x + 1

        out = fault.retry(flaky, 41, retries=3, backoff_s=0.01,
                          on_retry=lambda a, e: calls.__setitem__(
                              "restored", calls["restored"] + 1))
        assert out == 42 and calls["restored"] == 2

    def test_retry_exhausts(self):
        def dead(_):
            raise RuntimeError("down")
        with pytest.raises(RuntimeError):
            fault.retry(dead, 0, retries=1, backoff_s=0.01)

    def test_elastic_mesh_shape(self):
        assert fault.elastic_mesh_shape(256, 16) == (16, 16)
        assert fault.elastic_mesh_shape(240, 16) == (15, 16)   # lost a host
        assert fault.elastic_mesh_shape(512, 16, pod=2) == (2, 16, 16)
        with pytest.raises(ValueError):
            fault.elastic_mesh_shape(8, 16)
