"""Per-architecture smoke tests: REDUCED config, one forward/train step on
CPU, asserting output shapes + no NaNs. Full configs are only exercised via
the dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models import registry

# the hybrid/enc-dec archs and the largest dense/MoE towers dominate suite
# wall time (SSM scan + big compiles); their smoke coverage rides in the
# slow tier, PR-gating keeps one representative per family
_HEAVY = ("zamba2-7b", "whisper-large-v3", "chameleon-34b",
          "granite-8b", "llama4-scout-17b-a16e")
ARCH_NAMES = list(list_configs())
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
         for a in ARCH_NAMES]


def _smoke_batch(cfg, rng, B=2, S=32):
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(rng, (B, max(S // 8, 8)), 0,
                                         cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(get_config(name))
            params, specs = registry.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params, specs)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params, _ = built(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, extras = registry.apply_train(cfg, params, batch)
    want_len = batch["tokens"].shape[1]
    assert logits.shape == (2, want_len, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(extras["aux_loss"]))


@pytest.mark.slow  # training path: covered by the full tier on main pushes
@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_decreases_loss_signal(arch, built):
    """One SGD step on the smoke batch must produce finite grads that
    change the loss (catches disconnected graphs)."""
    cfg, params, _ = built(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(2))
    tokens = batch["tokens"]

    def loss_fn(p):
        logits, extras = registry.apply_train(cfg, p, batch)
        tgt = jnp.roll(tokens, -1, axis=1)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
        return nll[:, :-1].mean() + extras["aux_loss"]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.abs(g).sum()), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0.0, f"{arch}: zero/NaN grads"
    lr = 1e-2
    p2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = loss_fn(p2)
    assert bool(jnp.isfinite(l1)) and float(l1) != float(l0)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_structure(arch, built):
    cfg, params, specs = built(arch)
    t1 = jax.tree.structure(jax.tree.map(lambda x: 0, params))
    t2 = jax.tree.structure(jax.tree.map(
        lambda x: 0, specs, is_leaf=lambda x: isinstance(x, tuple)))
    assert t1 == t2, f"{arch}: params/specs trees diverge"
    # every spec tuple has the right rank
    def check(p, s):
        assert len(s) == p.ndim, f"{arch}: spec rank {s} vs shape {p.shape}"
    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, tuple) and not
                 any(isinstance(e, dict) for e in x))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, built):
    """Serving equivalence: prefill(t[:-1]) + decode(t[-1]) logits must
    match a full forward pass at the last position (dense/exact paths)."""
    cfg, params, _ = built(arch)
    cfg = cfg.replace(hdp=None)  # exact-path equivalence
    B, S = 2, 16
    rng = jax.random.PRNGKey(3)
    batch = _smoke_batch(cfg, rng, B=B, S=S)
    tokens = batch["tokens"]
    T = tokens.shape[1]

    logits_full, _ = registry.apply_train(cfg, params, batch)

    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_len"] = S
    cache = registry.init_cache(cfg, B, max_len=T + 4, **kw)
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    _, cache, _ = registry.apply_prefill(cfg, params, pre_batch, cache)
    logits_dec, _, _ = registry.apply_decode(
        cfg, params, tokens[:, -1:], cache, jnp.int32(T - 1))

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=2e-2, atol=2e-3)


def test_param_counts_match_analytic():
    for arch in ARCH_NAMES:
        cfg = reduced(get_config(arch))
        params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = registry.param_count(cfg)
        assert abs(real - analytic) / real < 0.05, (
            f"{arch}: analytic {analytic} vs real {real}")
