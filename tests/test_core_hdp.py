"""Unit tests for the HDP core: faithfulness to the paper's Algorithm 2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HDPConfig, dense_attention_reference, hdp_attention,
    hdp_attention_reference, int_frac_split, quantize_fixed,
    topk_attention, topk_block_mask,
)
from repro.core import blocking
from repro.core.quant import quantize_and_split

jax.config.update("jax_enable_x64", False)


def rnd(*shape, seed=0, scale=2.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------- quantizer
class TestQuant:
    def test_grid_and_range(self):
        x = rnd(64, 32, seed=1, scale=40.0)
        q = quantize_fixed(x, int_bits=4, frac_bits=12)
        assert float(q.max()) <= 16.0 - 2**-12 + 1e-9
        assert float(q.min()) >= -16.0
        scaled = np.asarray(q, np.float64) * 2**12
        np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-6)

    def test_split_identity_and_range(self):
        x = quantize_fixed(rnd(128, seed=2, scale=5.0))
        i, f = int_frac_split(x)
        np.testing.assert_allclose(np.asarray(i + f), np.asarray(x), rtol=0, atol=1e-6)
        assert np.all(np.asarray(i) == np.trunc(np.asarray(i)))
        assert np.all(np.abs(np.asarray(f)) < 1.0)

    def test_near_zero_has_zero_integer(self):
        x = jnp.linspace(-0.999, 0.999, 101)
        i, _ = int_frac_split(x)
        assert np.all(np.asarray(i) == 0.0)


# ------------------------------------------------------------- block algebra
class TestBlocking:
    def test_block_abs_sum_matches_loop(self):
        x = rnd(8, 12, seed=3)
        theta = blocking.block_abs_sum(x, 2, 2)
        ref = np.zeros((4, 6))
        xn = np.abs(np.asarray(x))
        for i in range(4):
            for j in range(6):
                ref[i, j] = xn[2 * i : 2 * i + 2, 2 * j : 2 * j + 2].sum()
        np.testing.assert_allclose(np.asarray(theta), ref, rtol=1e-6)

    @pytest.mark.parametrize("rho", [0.0, 0.3, 0.9, -0.3, -0.9])
    def test_row_threshold_both_branches(self, rho):
        theta = jnp.abs(rnd(5, 8, seed=4))
        th = blocking.row_threshold(theta, rho)
        t = np.asarray(theta)
        if rho >= 0:
            expect = rho * t.max(-1) + (1 - rho) * t.mean(-1)
        else:
            expect = -rho * t.min(-1) + (1 + rho) * t.mean(-1)
        np.testing.assert_allclose(np.asarray(th)[..., 0], expect, rtol=1e-5)

    def test_max_block_always_survives(self):
        # Theta <= max for rho in [0,1) -> at least one block kept per row.
        for seed in range(5):
            theta = jnp.abs(rnd(7, 9, seed=seed))
            th = blocking.row_threshold(theta, 0.95)
            keep = blocking.block_keep_mask(theta, th)
            assert bool(keep.any(axis=-1).all())

    def test_expand_mask(self):
        m = jnp.array([[True, False], [False, True]])
        e = blocking.expand_block_mask(m, 2, 3)
        assert e.shape == (4, 6)
        assert bool(e[0, 0]) and not bool(e[0, 3]) and bool(e[2, 3])

    def test_poly_softmax_close_to_exact(self):
        s = rnd(4, 64, seed=6, scale=3.0)
        exact = jax.nn.softmax(s, axis=-1)
        approx = blocking.approx_softmax(s)
        assert float(jnp.abs(exact - approx).max()) < 0.02

    def test_masked_softmax_exclusion(self):
        s = rnd(3, 8, seed=7)
        keep = jnp.arange(8)[None, :] < 4
        p = blocking.masked_softmax(s, keep)
        np.testing.assert_allclose(np.asarray(p[:, 4:]), 0.0)
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)


# --------------------------------------------------------------- Algorithm 2
class TestHDPAttention:
    @pytest.mark.parametrize("rho", [0.5, -0.5])
    @pytest.mark.parametrize("block", [(2, 2), (4, 4), (2, 8)])
    def test_fast_path_matches_reference(self, rho, block):
        cfg = HDPConfig(rho_b=rho, block_q=block[0], block_k=block[1],
                        tau_h=0.0, normalize_head_score=True)
        q, k, v = (rnd(2, 3, 16, 8, seed=s) for s in (1, 2, 3))
        out_fast, st_fast = hdp_attention(q, k, v, cfg)
        out_ref, st_ref = hdp_attention_reference(q, k, v, cfg)
        np.testing.assert_allclose(np.asarray(out_fast), np.asarray(out_ref),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(st_fast.keep_blocks),
                                      np.asarray(st_ref.keep_blocks))
        np.testing.assert_array_equal(np.asarray(st_fast.head_kept),
                                      np.asarray(st_ref.head_kept))

    def test_identity_three_term_equals_qk_minus_ff(self):
        x = rnd(32, 16, seed=8)
        y = rnd(24, 16, seed=9)
        _, ix, fx = quantize_and_split(x)
        _, iy, fy = quantize_and_split(y)
        three = ix @ iy.T + ix @ fy.T + fx @ iy.T
        ident = (ix + fx) @ (iy + fy).T - fx @ fy.T
        np.testing.assert_allclose(np.asarray(three), np.asarray(ident),
                                   rtol=1e-4, atol=1e-4)

    def test_disabled_matches_dense(self):
        cfg = HDPConfig(enabled=False)
        q, k, v = (rnd(2, 16, 8, seed=s) for s in (4, 5, 6))
        out, st = hdp_attention(q, k, v, cfg)
        ref = dense_attention_reference(q, k, v)
        assert st is None
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)

    def test_no_pruning_equals_quantized_dense(self):
        # rho=0 -> Theta = mean (some pruning); to get *no* pruning use
        # block_pruning=False, head_pruning=False, approx=False.
        # calib="none" pins the paper-literal grid so the reference is
        # plain quantize_fixed.
        cfg = HDPConfig(block_pruning=False, head_pruning=False,
                        approx=False, calib="none")
        q, k, v = (rnd(2, 16, 8, seed=s) for s in (7, 8, 9))
        out, _ = hdp_attention(q, k, v, cfg)
        ref = dense_attention_reference(
            quantize_fixed(q), quantize_fixed(k), v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)

    def test_no_pruning_calibrated_close_to_dense(self):
        # with calibration the quantized-but-unpruned path should be very
        # close to true dense attention (grid resolution only)
        cfg = HDPConfig(block_pruning=False, head_pruning=False,
                        approx=False, calib="max")
        q, k, v = (rnd(2, 16, 8, seed=s) for s in (7, 8, 9))
        out, _ = hdp_attention(q, k, v, cfg)
        ref = dense_attention_reference(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-3)

    def test_head_pruning_zeroes_output(self):
        cfg = HDPConfig(tau_h=1e12, normalize_head_score=False)  # prune all
        q, k, v = (rnd(2, 16, 8, seed=s) for s in (10, 11, 12))
        out, st = hdp_attention(q, k, v, cfg)
        assert not bool(st.head_kept.any())
        np.testing.assert_allclose(np.asarray(out), 0.0)
        assert float(st.head_sparsity) == 1.0

    def test_tau_zero_keeps_typical_heads(self):
        cfg = HDPConfig(tau_h=0.0)
        q, k, v = (rnd(4, 32, 16, seed=s, scale=3.0) for s in (13, 14, 15))
        out, st = hdp_attention(q, k, v, cfg)
        assert bool(st.head_kept.all())
        assert float(st.head_sparsity) == 0.0

    def test_causal_masking(self):
        cfg = HDPConfig(causal=True, block_pruning=False, head_pruning=False,
                        approx=False, calib="none")
        q, k, v = (rnd(16, 8, seed=s) for s in (16, 17, 18))
        out, _ = hdp_attention(q, k, v, cfg)
        ref = dense_attention_reference(
            quantize_fixed(q), quantize_fixed(k), v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)

    def test_higher_rho_prunes_more(self):
        q, k, v = (rnd(2, 64, 16, seed=s, scale=3.0) for s in (19, 20, 21))
        sp = []
        for rho in (0.1, 0.5, 0.9):
            _, st = hdp_attention(q, k, v, HDPConfig(rho_b=rho))
            sp.append(float(st.block_sparsity))
        assert sp[0] <= sp[1] <= sp[2]
        assert sp[2] > 0.3

    def test_decode_mode_kv_blocks(self):
        # Lq=1 with block_q=1: KV-block pruning for decode (TPU adaptation).
        cfg = HDPConfig(block_q=1, block_k=4, causal=True)
        q = rnd(1, 16, seed=22)
        k = rnd(64, 16, seed=23, scale=3.0)
        v = rnd(64, 16, seed=24)
        out, st = hdp_attention(q, k, v, cfg, q_offset=63)
        assert out.shape == (1, 16)
        assert st.keep_blocks.shape == (1, 16)
        assert not bool(jnp.isnan(out).any())

    def test_approximation_error_small(self):
        q, k, v = (rnd(4, 64, 32, seed=s) for s in (25, 26, 27))
        # Score level: the dropped FF term is small vs the full product.
        from repro.core.quant import quantize_and_split
        _, iq, fq = quantize_and_split(q)
        _, ik, fk = quantize_and_split(k)
        full = (iq + fq) @ jnp.swapaxes(ik + fk, -1, -2)
        ff = fq @ jnp.swapaxes(fk, -1, -2)
        assert float(jnp.linalg.norm(ff) / jnp.linalg.norm(full)) < 0.10
        # Output level: direction is preserved (softmax amplifies the rest).
        cfg = HDPConfig(block_pruning=False, head_pruning=False, approx=True)
        out, _ = hdp_attention(q, k, v, cfg)
        ref = dense_attention_reference(q, k, v)
        cos = float((out * ref).sum() / (jnp.linalg.norm(out) * jnp.linalg.norm(ref)))
        assert cos > 0.98


# ------------------------------------------------------------------- Top-K
class TestTopK:
    def test_keep_ratio_exact(self):
        s = rnd(16, 16, seed=28)
        keep = topk_block_mask(s, 2, 2, keep_ratio=0.25)
        assert keep.shape == (8, 8)
        np.testing.assert_array_equal(np.asarray(keep.sum(-1)), 2)

    def test_topk_oracle_keeps_biggest(self):
        s = jnp.zeros((4, 8)).at[0, 0].set(100.0).at[0, 5].set(50.0)
        keep = topk_block_mask(s, 2, 2, keep_ratio=0.5)
        assert bool(keep[0, 0]) and bool(keep[0, 2])

    def test_topk_attention_runs(self):
        q, k, v = (rnd(2, 32, 16, seed=s) for s in (29, 30, 31))
        out, keep = topk_attention(q, k, v, 2, 2, 0.5, causal=True)
        assert out.shape == q.shape
        assert not bool(jnp.isnan(out).any())
