"""Benchmark substrate correctness: the pluggable-attention forward must
equal the production model forward, or every figure analog is meaningless."""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, "/root/repo")  # benchmarks package lives at repo root

from benchmarks import common  # noqa: E402
from repro.core.hdp import dense_attention_reference  # noqa: E402
from repro.models import registry  # noqa: E402


@pytest.fixture(scope="module")
def tiny():
    cfg = common.model_cfg("tiny")
    params, _ = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def test_forward_with_attention_matches_model(tiny):
    cfg, params, toks = tiny
    ref, _ = registry.apply_train(cfg, params, {"tokens": toks})
    got = common.forward_with_attention(
        cfg, params, toks,
        lambda li, q, k, v: dense_attention_reference(q, k, v, causal=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capture_layout(tiny):
    cfg, params, toks = tiny
    caps = common.capture_qkv(cfg, params, toks)
    assert len(caps) == cfg.n_layers
    B, S = toks.shape
    for c in caps:
        assert c["q"].shape == (B, cfg.n_heads, S, cfg.hd)
        assert bool(jnp.isfinite(c["q"]).all())


def test_agreement_is_one_for_dense(tiny):
    cfg, params, toks = tiny
    ag = common.agreement_with(
        cfg, params,
        lambda li, q, k, v: dense_attention_reference(q, k, v, causal=True),
        [np.asarray(toks)])
    assert ag == 1.0


def test_eval_batches_disjoint_from_training_stream():
    a = common.eval_batches(1, batch=4)[0]
    from repro.data.pipeline import DataConfig, make_source
    train = make_source(DataConfig(common.VOCAB, common.SEQ, 4,
                                   seed=3)).batch_at(0)
    assert not np.array_equal(a, train)
