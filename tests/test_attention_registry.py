"""Backend conformance matrix + auto-selection + deprecation shim.

Load-bearing guarantees pinned here:

* every registered backend that ``supports()`` a call agrees with the
  ``reference`` oracle on a (mode x layout x causal x hdp-on/off) grid —
  token-for-token up to float-reduction-order tolerance (the backends
  compute identical math with different reduction schedules; see ATOL);
* off-TPU auto-selection resolves to the documented fallback chain for
  each call shape (pallas -> xla -> reference, pallas never auto
  off-TPU), and REPRO_ATTN_BACKEND forces the *default* spec only;
* the deprecated ``attn_backend=``/``cache_backend=`` string kwargs keep
  working end-to-end through Engine and launch/serve.py, emitting exactly
  ONE DeprecationWarning (these tests are the only exemption from the
  fast CI tier's ``-W error::DeprecationWarning``);
* ``Engine.summary()`` reports the resolved backend per phase.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import (AttnCall, AttnSpec, BackendUnsupported,
                             attention, get_backend, list_backends,
                             resolve_backend, spec_from_legacy)
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.config import HDPConfig
from repro.models.attention import scout_int8
from repro.serving import Engine, Request

F32 = jnp.float32

# float tolerance for backend-vs-oracle agreement: the implementations
# compute the same masked/blocked math with different reduction orders
# (scan-per-block vs full materialize vs online softmax), so bit equality
# is not guaranteed — agreement is pinned to this documented tolerance.
ATOL = 2e-5

B, N, G, HD = 1, 2, 2, 8
SQ = SK = 16
HDP = HDPConfig(block_q=4, block_k=4, rho_b=0.5, tau_h=0.0,
                normalize_head_score=True, calib="max")


def _qkv(seed, sq=SQ, sk=SK):
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, N, G, sq, HD), F32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, sk, N, HD), F32)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, sk, N, HD), F32)
    return q, k, v


def _paged_setup(seed, hdp, n_pages=4):
    """One-slot paged cache: pools + table + positions (all pages visible)."""
    ps = hdp.block_k
    P = 1 + n_pages                       # page 0 = reserved scratch
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, N, G, 1, HD), F32)
    ks = jax.random.normal(jax.random.fold_in(rng, 1), (P, ps, N, HD), F32)
    vs = jax.random.normal(jax.random.fold_in(rng, 2), (P, ps, N, HD), F32)
    cache = {"k_pages": ks, "v_pages": vs, "k_scout": scout_int8(ks, hdp)}
    table = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n_pages)
    sk = n_pages * ps
    pos = jnp.full((B, 1), sk - 1, jnp.int32)
    q_pos = pos[:, None, None, :]
    ar = jnp.arange(sk)
    k_pos = jnp.where(ar[None] <= pos, ar, -1)[:, None, None, :]
    return q, cache, table, q_pos, k_pos


def _run(call, backend_name, seed=0):
    spec = AttnSpec(backend=backend_name, allow_fallback=False)
    if call.layout == "paged":
        hdp = call.hdp if call.hdp is not None else HDP
        q, cache, table, q_pos, k_pos = _paged_setup(seed, hdp)
        out, _ = attention(q, None, None, call, spec=spec, q_pos=q_pos,
                           k_pos=k_pos, cache=cache, page_table=table)
        return out
    if call.mode == "decode":
        q, k, v = _qkv(seed, sq=1)
        q_pos, k_pos = jnp.asarray([SK - 1]), jnp.arange(SK)
    else:
        q, k, v = _qkv(seed)
        q_pos = k_pos = jnp.arange(SQ)
    out, _ = attention(q, k, v, call, spec=spec, q_pos=q_pos, k_pos=k_pos)
    return out


def _grid():
    cells = []
    for mode in ("prefill", "decode"):
        for causal in (True, False):
            for hdp_on in (True, False):
                hdp = HDP.replace(causal=causal) if hdp_on else None
                cells.append(AttnCall(
                    mode=mode, layout="dense", causal=causal, hdp=hdp,
                    self_aligned=(mode == "prefill")))
    for hdp_on in (True, False):
        cells.append(AttnCall(
            mode="decode", layout="paged", causal=True,
            hdp=HDP.replace(causal=True, calib="none") if hdp_on else None))
    return cells


def _cell_id(call):
    return (f"{call.mode}-{call.layout}-"
            f"{'causal' if call.causal else 'full'}-"
            f"{'hdp' if call.hdp is not None else 'dense'}")


GRID = _grid()


@pytest.mark.parametrize("call", GRID, ids=_cell_id)
def test_backends_agree_with_reference(call):
    ref = _run(call, "reference")
    ran = []
    for b in list_backends():
        if b.name == "reference" or not b.supports(call):
            continue
        out = _run(call, b.name)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=ATOL, rtol=ATOL,
            err_msg=f"{b.name} disagrees with reference on {_cell_id(call)}")
        ran.append(b.name)
    assert ran, f"no production backend supports {_cell_id(call)}"


def test_every_backend_covered_by_grid():
    """Each of the seven registered backends runs in >= 1 conformance cell."""
    names = {b.name for b in list_backends()}
    assert names == {"reference", "xla_dense", "xla_hdp", "paged_hdp_decode",
                     "pallas_flash", "pallas_hdp_block",
                     "pallas_paged_decode"}
    covered = {"reference"}
    for call in GRID:
        covered |= {b.name for b in list_backends() if b.supports(call)}
    assert covered == names


def test_reference_matches_core_oracle():
    """The model-layout oracle agrees with core.hdp's Algorithm 2
    transliteration on an aligned causal self-attention cell."""
    from repro.core.hdp import hdp_attention_reference
    hdp = HDP.replace(causal=True)
    q, k, v = _qkv(7)
    call = AttnCall(mode="prefill", layout="dense", causal=True, hdp=hdp,
                    self_aligned=True)
    out = _run(call, "reference", seed=7)
    # core layout: [B,H,S,hd] with k/v repeated across the GQA group
    qh = q.reshape(B, N * G, SQ, HD)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    core, _ = hdp_attention_reference(qh, kh, vh, hdp)
    np.testing.assert_allclose(np.asarray(out.reshape(B, N * G, SQ, HD)),
                               np.asarray(core), atol=ATOL, rtol=ATOL)


# -------------------------------------------------------------- resolution
@pytest.fixture
def no_env(monkeypatch):
    monkeypatch.delenv("REPRO_ATTN_BACKEND", raising=False)


@pytest.mark.parametrize("call,expect", [
    (AttnCall(mode="prefill", hdp=HDP, self_aligned=True), "xla_hdp"),
    (AttnCall(mode="prefill", self_aligned=True), "xla_dense"),
    (AttnCall(mode="prefill", trainable=True, hdp=HDP), "xla_hdp"),
    (AttnCall(mode="decode", hdp=HDP, per_slot=True), "xla_hdp"),
    (AttnCall(mode="decode", layout="paged", hdp=HDP, per_slot=True),
     "paged_hdp_decode"),
    (AttnCall(mode="decode", layout="paged", per_slot=True), "xla_dense"),
], ids=["prefill-hdp", "prefill-dense", "train-hdp", "decode-hdp",
        "paged-hdp", "paged-dense"])
def test_auto_resolution_off_tpu(call, expect, no_env):
    assert jax.default_backend() != "tpu"
    assert resolve_backend(call).name == expect


def test_explicit_pallas_and_fallback(no_env):
    paged = AttnCall(mode="decode", layout="paged",
                     hdp=HDP.replace(causal=True), per_slot=True)
    spec = AttnSpec(backend="pallas")
    # the "pallas" family tag prefers the gather-free page-table-native
    # kernel; the densifying block kernel stays explicitly addressable
    assert resolve_backend(paged, spec).name == "pallas_paged_decode"
    assert resolve_backend(
        paged, AttnSpec(backend="pallas_hdp_block")).name == "pallas_hdp_block"
    # non-causal paged calls can't use the gather-free kernel (its per-row
    # validity is an upper bound) but the block kernel still serves them
    noncausal = AttnCall(mode="decode", layout="paged", hdp=HDP,
                         causal=False, per_slot=True)
    assert resolve_backend(noncausal, spec).name == "pallas_hdp_block"
    # the FUM kernels cannot express a sliding window's lower bound ->
    # windowed calls fall down the chain to the XLA implementation
    windowed = paged.replace(window=8)
    assert resolve_backend(windowed, spec).name == "paged_hdp_decode"
    with pytest.raises(BackendUnsupported):
        resolve_backend(windowed, spec.replace(allow_fallback=False))
    with pytest.raises(KeyError):
        resolve_backend(paged, AttnSpec(backend="not-a-backend"))


def test_env_var_forces_every_auto_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_ATTN_BACKEND", "reference")
    call = AttnCall(mode="prefill", hdp=HDP, self_aligned=True)
    assert resolve_backend(call).name == "reference"
    # "auto" consults the env even through an explicit spec (a spec that
    # only pins the layout must not dodge the CI reference leg) ...
    assert resolve_backend(call, AttnSpec(layout="dense")).name == "reference"
    # ... but explicit non-auto requests win over the env override
    assert resolve_backend(call, AttnSpec(backend="xla")).name == "xla_hdp"


def test_engine_validates_per_mode_overrides():
    with pytest.raises(ValueError, match="decode"):
        Engine(_cfg(), max_batch=1, max_len=32,
               attn=AttnSpec(decode="palas"))


def test_supports_capability_edges():
    trainable = AttnCall(mode="prefill", hdp=HDP, self_aligned=True,
                         trainable=True)
    assert not get_backend("pallas_hdp_block").supports(trainable)
    assert not get_backend("pallas_flash").supports(
        AttnCall(mode="prefill", self_aligned=True, trainable=True))
    # disabled HDP configs normalize to hdp=None at construction
    off = AttnCall(mode="prefill", hdp=HDP.replace(enabled=False))
    assert off.hdp is None
    with pytest.raises(ValueError):
        AttnCall(mode="prefill", layout="paged")


# -------------------------------------------------------- deprecation shim
def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 250, size=10).tolist() for _ in range(n)]


def _cfg(calib="none"):
    cfg = reduced(get_config("qwen2-1.5b"))
    return cfg.replace(hdp=cfg.hdp.replace(calib=calib))


@pytest.mark.filterwarnings("always::DeprecationWarning")
def test_engine_legacy_kwargs_single_warning():
    cfg = _cfg()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = Engine(cfg, max_batch=1, max_len=64, prefill_buckets=(16,),
                     cache_backend="paged", attn_backend="pallas")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in dep]
    assert "AttnSpec" in str(dep[0].message)
    # the shim maps onto the same spec the new API would build
    assert eng.paged
    assert eng.attn_spec.backend == "pallas"
    eng.submit(Request(0, _prompts(1)[0], max_new_tokens=2))
    toks = eng.run()[0].tokens
    assert len(toks) == 2

    new = Engine(cfg, params=eng.params, max_batch=1, max_len=64,
                 prefill_buckets=(16,),
                 attn=AttnSpec(backend="pallas", layout="paged"))
    new.submit(Request(0, _prompts(1)[0], max_new_tokens=2))
    assert new.run()[0].tokens == toks


@pytest.mark.filterwarnings("always::DeprecationWarning")
def test_serve_legacy_flags_end_to_end():
    from repro.launch import serve
    args = serve.build_parser().parse_args(
        ["--arch", "qwen2-1.5b", "--requests", "1", "--max-new", "2",
         "--attn-backend", "xla", "--cache-backend", "dense"])
    with pytest.warns(DeprecationWarning):
        out = serve.run(args)
    assert out["completed"] == 1
    assert out["backend"] == "dense"
    assert out["attn_decode"] == "xla_hdp"


def test_engine_rejects_unknown_strings():
    with pytest.raises(ValueError):
        Engine(_cfg(), max_batch=1, max_len=32,
               attn="definitely-not-a-backend")
    with pytest.raises(ValueError):
        spec_from_legacy(attn_backend="cuda")
    with pytest.raises(ValueError):
        spec_from_legacy(cache_backend="ring")


def test_engine_summary_reports_resolved_backends(no_env):
    eng = Engine(_cfg(), max_batch=1, max_len=32, prefill_buckets=(16,))
    s = eng.summary()
    assert s["attn_backend_prefill"] == "xla_hdp"
    assert s["attn_backend_decode"] == "paged_hdp_decode"
    dense = Engine(_cfg(), params=eng.params, max_batch=1, max_len=32,
                   attn=AttnSpec(backend="reference", layout="dense"))
    s = dense.summary()
    assert s["attn_backend_prefill"] == "reference"
    assert s["attn_backend_decode"] == "reference"
