"""Mesh-sharded serving: tensor-parallel engines + data-parallel replicas.

Load-bearing guarantees pinned here:

* TP is a pure layout transform: an engine serving over a mesh-sharded
  page pool (int8 codes + per-page scales split along kv heads, decode
  dispatched through shard_map on the "model" axis) generates tokens
  BYTE-IDENTICAL to the unsharded engine — at TP=2 and TP=4, composed
  with the fused decode loop, speculative decode, the prefix cache and
  the stream scheduler;
* the pool really is sharded, never replicated: per-shard resident
  bytes == total pool bytes / TP, and every pool leaf carries a
  NamedSharding that splits its kv-head axis across "model";
* the FUM/no-DMA contract holds per shard: NaN-poisoning free pages of
  the SHARDED pool (both sentinel channels) cannot change a token;
* mesh resolution: explicit ``tp=`` must divide the kv heads and fit
  the device count (errors), a ``mesh=`` disagreeing with ``tp=``
  errors, while the REPRO_MESH_TP env default DEGRADES silently so a
  CI matrix can run the whole suite under it;
* DP replicas behind ``ReplicaSet`` share one params tree, dispatch by
  prefix affinity then least-loaded, and their merged stream yields
  the same tokens the single engine produces.

The whole module needs a multi-device host: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI's mesh legs
export it; single-device runs skip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import AttnSpec
from repro.configs import get_config
from repro.configs.base import reduced
from repro.launch.mesh import make_serving_mesh
from repro.serving import Engine, ReplicaSet, Request

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices: export "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")


def _cfg(arch="qwen2-1.5b", calib="none"):
    cfg = reduced(get_config(arch))
    return cfg.replace(hdp=cfg.hdp.replace(enabled=True, calib=calib))


def _prompts(n, lo=4, hi=24, seed=0, vocab=250, shared=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(1, vocab, size=shared).tolist()
    return [pre + rng.integers(1, vocab,
                               size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def _serve(cfg, params, prompts, *, max_new=5, **kw):
    eng = Engine(cfg, params=params, max_batch=2, max_len=96,
                 prefill_buckets=(16, 32, 64), **kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=max_new))
    res = eng.run()
    return eng, {u: r.tokens for u, r in res.items()}


# --------------------------------------------------------- mesh construction
def test_make_serving_mesh_shape():
    mesh = make_serving_mesh(tp=2)
    assert dict(mesh.shape) == {"data": 1, "model": 2}
    mesh = make_serving_mesh(tp=2, dp=2)
    assert dict(mesh.shape) == {"data": 2, "model": 2}
    with pytest.raises(RuntimeError, match="device"):
        make_serving_mesh(tp=64, dp=64)
    with pytest.raises(ValueError):
        make_serving_mesh(tp=0)


def test_engine_tp_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="divisible"):
        Engine(cfg, max_batch=1, max_len=32, tp=3)     # 2 kv heads % 3
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, max_batch=1, max_len=32, tp=2,
               attn=AttnSpec(layout="dense"))
    mesh = make_serving_mesh(tp=2)
    with pytest.raises(ValueError, match="model axis"):
        Engine(cfg, max_batch=1, max_len=32, tp=1, mesh=mesh)


def test_env_default_degrades_silently(monkeypatch):
    cfg = _cfg()
    monkeypatch.setenv("REPRO_MESH_TP", "2")
    assert Engine(cfg, max_batch=1, max_len=32).tp == 2
    # non-divisible head count: degrade, don't error (CI runs the whole
    # suite under the env)
    monkeypatch.setenv("REPRO_MESH_TP", "3")
    assert Engine(cfg, max_batch=1, max_len=32).tp == 1
    monkeypatch.setenv("REPRO_MESH_TP", "2")
    assert Engine(cfg, max_batch=1, max_len=32,
                  attn=AttnSpec(layout="dense")).tp == 1
    # explicit kwarg wins over the env
    monkeypatch.delenv("REPRO_MESH_TP")
    assert Engine(cfg, max_batch=1, max_len=32, tp=2).tp == 2


# ------------------------------------------------------------- byte identity
@pytest.mark.parametrize("tp,arch", [(2, "qwen2-1.5b"),
                                     (4, "olmoe-1b-7b")])
def test_tp_byte_identity(tp, arch):
    """Sharded decode must not change a single token — the all-gather
    concatenates exact per-shard head outputs, it never float-reduces."""
    cfg = _cfg(arch)
    prompts = _prompts(4, seed=3)
    eng, ref = _serve(cfg, None, prompts)
    eng_tp, got = _serve(cfg, eng.params, prompts, tp=tp)
    assert got == ref, f"tp={tp} changed the generated tokens"
    assert eng_tp.tp == tp and dict(eng_tp.mesh.shape)["model"] == tp


@pytest.mark.parametrize("feat", [
    {"decode_horizon": 4},
    {"spec_decode": True, "draft_len": 3},
    {"prefix_cache": True},
    {"stream_sched": True},
    pytest.param({"decode_horizon": 4, "spec_decode": True,
                  "prefix_cache": True, "stream_sched": True},
                 id="everything-on", marks=pytest.mark.slow),
])
def test_tp2_composes_with_serving_features(feat):
    cfg = _cfg()
    shared = 32 if feat.get("prefix_cache") else 0
    prompts = _prompts(4, seed=5, shared=shared)
    eng, ref = _serve(cfg, None, prompts, **feat)
    _, got = _serve(cfg, eng.params, prompts, tp=2, **feat)
    assert got == ref, f"tp=2 + {feat} changed the generated tokens"


# ------------------------------------------------------------- pool sharding
def test_pool_sharded_not_replicated():
    cfg = _cfg()
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 tp=2)
    assert eng.pages.pool_bytes_per_shard() * 2 == eng.pages.pool_bytes()
    from repro.distribution.tp import POOL_HEAD_AXIS
    for name, leaf in eng.pages.cache.items():
        ax = POOL_HEAD_AXIS[name]
        shardings = leaf.sharding.spec
        assert shardings[ax] == "model", \
            f"{name}: head axis {ax} not sharded over 'model' ({shardings})"
        assert leaf.shape[ax] == cfg.n_kv_heads


def test_summary_reports_mesh():
    cfg = _cfg()
    eng = Engine(cfg, max_batch=2, max_len=64, prefill_buckets=(16, 32),
                 tp=2)
    eng.submit(Request(0, _prompts(1, seed=1)[0], max_new_tokens=4))
    eng.run()
    m = eng.summary()
    assert m["tp"] == 2
    assert m["mesh_shape"] == {"data": 1, "model": 2}
    assert m["cache_bytes_pool_per_shard"] * 2 == m["cache_bytes_pool"]
    assert m["collective_bytes_per_layer"] > 0


def test_poisoned_free_pages_never_read_per_shard():
    """The no-DMA contract holds on the SHARDED pool: free pages of both
    shards NaN-poisoned through both sentinel channels, tokens
    unchanged (decode gathers only table-mapped pages on each shard)."""
    from repro.core.quant import POISON_CODE

    cfg = _cfg()
    prompts = _prompts(2, seed=7)
    eng, clean = _serve(cfg, None, prompts)

    eng2 = Engine(cfg, params=eng.params, max_batch=2, max_len=96,
                  prefill_buckets=(16, 32, 64), tp=2)
    for uid, p in enumerate(prompts):
        eng2.submit(Request(uid, p, max_new_tokens=5))
    eng2.step()                        # admit + first decode
    free = list(eng2.pages._free)
    assert free, "test needs unallocated pages"
    c = eng2.pages.cache
    idx = jnp.asarray(free)
    eng2.pages.cache = {
        **c,
        "k_pages": c["k_pages"].at[:, idx].set(POISON_CODE),
        "v_pages": c["v_pages"].at[:, idx].set(POISON_CODE),
        "k_scale": c["k_scale"].at[:, idx].set(jnp.nan),
        "v_scale": c["v_scale"].at[:, idx].set(jnp.nan),
    }
    res = eng2.run()
    got = {u: r.tokens for u, r in res.items()}
    assert got == clean, "NaN leaked from never-referenced sharded pages"


# ---------------------------------------------------------------- replicas
def test_replicaset_byte_identity_and_affinity():
    cfg = _cfg()
    prompts = _prompts(6, seed=9, shared=16)
    eng, ref = _serve(cfg, None, prompts, prefix_cache=True)

    rs = ReplicaSet.build(cfg, 2, params=eng.params, max_batch=2,
                          max_len=96, prefill_buckets=(16, 32, 64),
                          prefix_cache=True)
    homes = {}
    got = {}
    for uid, p in enumerate(prompts):
        homes[uid] = rs.submit(Request(uid, p, max_new_tokens=5))
    for r in rs.serve():
        got[r.uid] = r.tokens
    assert got == ref, "replica dispatch changed the generated tokens"
    # every prompt shares a 16-token prefix: once replica 0 has served
    # the first request, affinity must route the rest to the replica
    # holding the cached prefix pages
    assert len(set(id(e) for e in homes.values())) >= 1
    counts = rs.summary()["requests_per_replica"]
    assert sum(counts) == len(prompts)
    s = rs.summary()
    # tp reflects each replica's engine (1 here, unless the
    # REPRO_MESH_TP CI leg shards them — identity holds either way)
    assert s["dp"] == 2 and s["tp"] == rs.engines[0].tp


def test_replicaset_dp2_tp2_compose():
    cfg = _cfg()
    prompts = _prompts(4, seed=11)
    eng, ref = _serve(cfg, None, prompts)
    rs = ReplicaSet.build(cfg, 2, params=eng.params, max_batch=2,
                          max_len=96, prefill_buckets=(16, 32, 64), tp=2)
    got = {r.uid: r.tokens
           for r in rs.serve([Request(u, p, max_new_tokens=5)
                              for u, p in enumerate(prompts)])}
    assert got == ref, "dp=2 x tp=2 changed the generated tokens"
    s = rs.summary()
    assert s["dp"] == 2 and s["tp"] == 2
    assert s["mesh_shape"] == {"data": 1, "model": 2}
    assert s["cache_bytes_pool_per_shard"] * 2 \
        == rs.engines[0].pages.pool_bytes()


def test_replicaset_least_loaded_dispatch():
    cfg = _cfg()
    rs = ReplicaSet.build(cfg, 2, max_batch=2, max_len=64,
                          prefill_buckets=(16, 32))
    prompts = _prompts(4, seed=13)
    picked = [rs.submit(Request(u, p, max_new_tokens=3))
              for u, p in enumerate(prompts)]
    # no prefix cache: dispatch alternates by load
    assert picked[0] is not picked[1]
    rs.run()
    assert sorted(rs.results()) == [0, 1, 2, 3]
