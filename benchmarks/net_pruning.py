"""Fig. 10 analog — net pruning: blocks + heads + approximation together.

Grid over (rho_B, tau_H percentile); net sparsity counts a block skipped
if its head was pruned OR the block itself was pruned (the paper's
accounting). Top-K at matched *net* sparsity is the reference: the paper
reports HDP reaches Top-K-level net sparsity (75% SST2 / 65% CoLA @ -1%)
because head pruning removes blocks Top-K would keep inside unimportant
heads.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import topk
from repro.core.config import HDPConfig
from repro.core.hdp import hdp_attention
from benchmarks.head_pruning import theta_head_samples

RHOS = (0.2, 0.4, 0.6, 0.8)
TAU_PCTS = (0, 10, 25)


def _fn(hdp):
    def fn(li, q, k, v):
        return hdp_attention(q, k, v, hdp)[0]
    return fn


def _topk_fn(keep, block):
    def fn(li, q, k, v):
        return topk.topk_attention(q, k, v, block, block, keep,
                                   causal=True)[0]
    return fn


def run(scale: str = "base", n_eval: int = 2,
        train_steps: int = 400) -> List[Dict]:
    cfg, params = common.train_model(scale, steps=train_steps)
    batches = common.eval_batches(n_eval)
    caps = common.capture_qkv(cfg, params, jnp.asarray(batches[0]))
    base = HDPConfig(rho_b=0.3, block_q=2, block_k=2, approx=True,
                     head_pruning=True, tau_h=-1.0, causal=True)
    th = theta_head_samples(cfg, params, batches[:1],
                            base.replace(block_pruning=False))
    rows = []
    for rho in RHOS:
        for pct in TAU_PCTS:
            tau = float(np.percentile(th, pct)) if pct else -1.0
            hdp = base.replace(rho_b=rho, tau_h=tau)
            ag = common.agreement_with(cfg, params, _fn(hdp), batches)
            nets = []
            for c in caps:
                _, st = hdp_attention(c["q"], c["k"], c["v"], hdp)
                nets.append(float(st.net_sparsity))
            rows.append({"method": "hdp", "rho_b": rho, "tau_pct": pct,
                         "net_sparsity": round(float(np.mean(nets)), 4),
                         "agreement": round(ag, 4)})
    for keep in (0.75, 0.5, 0.35, 0.25, 0.15, 0.08):
        ag = common.agreement_with(cfg, params, _topk_fn(keep, 2), batches)
        rows.append({"method": "topk", "rho_b": "", "tau_pct": "",
                     "net_sparsity": round(1 - keep, 4),
                     "agreement": round(ag, 4)})
    return rows


def main(quick: bool = False) -> List[Dict]:
    rows = run("base", n_eval=1 if quick else 2,
               train_steps=200 if quick else 400)
    print("# net_pruning (Fig.10 analog) scale=base")
    print("method,rho_b,tau_pct,net_sparsity,agreement")
    for r in rows:
        print(f"{r['method']},{r['rho_b']},{r['tau_pct']},"
              f"{r['net_sparsity']},{r['agreement']}")
    return rows


if __name__ == "__main__":
    main()
