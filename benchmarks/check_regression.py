"""Bench regression gate: fail CI on a serving decode-throughput cliff.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline bench_baseline_committed.json \
        --fresh BENCH_serving.json [--max-regress 0.20]

Compares the ``current`` row block of a freshly produced
BENCH_serving.json against the ``current`` block of the *committed* copy
(saved aside before the bench run overwrites the file), row-matched by
(bench, arch, hdp, backend, decode_horizon, attn_policy, kv_dtype,
tp, dp) — the policy component keeps serving_autotune's static-vs-cost
legs from colliding with rows of the other serving benches, the
kv_dtype component keeps serving_kvquant's int8-vs-fp32 legs apart
(rows from before the quantized pool normalize to "fp32"), and the
tp/dp components keep serving_tp's mesh legs apart (pre-mesh rows
normalize to tp=1, dp=1). The gate trips when the
MEAN decode_tok_s ratio across comparable rows drops below
``1 - max_regress`` — per-row wall-clock on shared CI runners is too
noisy to gate on individually, but a >20% mean collapse across every
serving bench is a real perf cliff, not scheduler jitter.

Exit codes: 0 = pass (or nothing comparable — a loud note is printed so
a silently-empty comparison cannot masquerade as a green gate), 1 =
regression, 2 = usage/IO error. Stdlib only: the gate must run before
any dependency install step can break.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_rows(path: str):
    """(quick flag, rows) of the file's ``current`` block."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"!! check_regression: cannot read {path}: {e}")
        return None, []
    cur = data.get("current") or {}
    return cur.get("quick"), cur.get("rows") or []


def _key(row: dict):
    # rows recorded before the autotune subsystem carry no attn_policy
    # (they all ran static selection), rows recorded before the
    # quantized KV pool carry no kv_dtype (they all served the fp32
    # pool), rows recorded before mesh-sharded serving carry no tp/dp
    # (they all served one unsharded engine), and rows recorded before
    # the fault-injection harness carry no faults field (they all ran
    # clean); normalizing all of these keeps old baselines comparable
    # — and keeps a chaos leg from ever being compared against a clean
    # one, since the fault plan is part of the cell identity
    return (row.get("bench"), row.get("arch"), row.get("hdp"),
            row.get("backend"), row.get("decode_horizon"),
            row.get("attn_policy") or "static",
            row.get("kv_dtype") or "fp32",
            row.get("tp") or 1, row.get("dp") or 1,
            row.get("faults") or "none")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_serving.json (copied aside "
                         "before the bench run)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_serving.json produced by this run")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="maximum tolerated mean decode tok/s drop "
                         "(fraction; default 0.20)")
    args = ap.parse_args(argv)

    base_quick, base_rows = _load_rows(args.baseline)
    fresh_quick, fresh_rows = _load_rows(args.fresh)
    if not base_rows or not fresh_rows:
        print("## check_regression: NOTHING COMPARABLE (missing or empty "
              "row blocks) — gate passes vacuously; fix the bench artifacts "
              "so it bites again")
        return 0
    if base_quick != fresh_quick:
        print(f"## check_regression: NOTHING COMPARABLE — baseline rows "
              f"were recorded with quick={base_quick}, fresh rows with "
              f"quick={fresh_quick}; refresh the committed "
              f"BENCH_serving.json at this run's settings so the gate "
              f"bites again")
        return 0

    base_by_key = {}
    for r in base_rows:
        if r.get("decode_tok_s"):
            base_by_key.setdefault(_key(r), r)
    ratios = []
    for r in fresh_rows:
        b = base_by_key.get(_key(r))
        if b is None or not r.get("decode_tok_s"):
            continue
        ratio = r["decode_tok_s"] / b["decode_tok_s"]
        ratios.append(ratio)
        flag = "  <-- slow" if ratio < 1.0 - args.max_regress else ""
        print(f"{'/'.join(str(k) for k in _key(r))}: "
              f"{b['decode_tok_s']:.2f} -> {r['decode_tok_s']:.2f} tok/s "
              f"(x{ratio:.2f}){flag}")
    if not ratios:
        print("## check_regression: NOTHING COMPARABLE (no matching rows "
              "with decode_tok_s) — gate passes vacuously; check the row "
              "keys if benches were renamed")
        return 0

    mean = sum(ratios) / len(ratios)
    floor = 1.0 - args.max_regress
    print(f"## mean decode tok/s ratio over {len(ratios)} comparable rows: "
          f"x{mean:.3f} (floor x{floor:.2f})")
    if mean < floor:
        print(f"!! REGRESSION: mean decode throughput fell "
              f"{1 - mean:.0%} vs the committed baseline "
              f"(> {args.max_regress:.0%} tolerated)")
        return 1
    print("## check_regression: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
