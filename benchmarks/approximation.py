"""Fig. 9 analog — block pruning with vs without the approximation.

The approximation drops the FQ.FK^T term (scores = QK^T - FQ.FK^T), which
also yields free near-zero pruning. Sweeps rho_B with approx on/off on
both model scales; reports agreement and attention cosine. Expected
paper behaviour: nearly free at base scale, more damaging at tiny scale
(fewer heads amplify per-head error).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.config import HDPConfig
from repro.core.hdp import dense_attention_reference, hdp_attention

RHOS = (0.01, 0.2, 0.4, 0.6, 0.8)


def _fn(hdp):
    def fn(li, q, k, v):
        return hdp_attention(q, k, v, hdp)[0]
    return fn


def run(scale: str, n_eval: int = 2, train_steps: int = 400) -> List[Dict]:
    cfg, params = common.train_model(scale, steps=train_steps)
    batches = common.eval_batches(n_eval)
    caps = common.capture_qkv(cfg, params, jnp.asarray(batches[0]))
    rows = []
    for rho in RHOS:
        for approx in (True, False):
            hdp = HDPConfig(rho_b=rho, block_q=2, block_k=2, approx=approx,
                            head_pruning=False, causal=True)
            ag = common.agreement_with(cfg, params, _fn(hdp), batches)
            cosines, sps = [], []
            for c in caps:
                out, st = hdp_attention(c["q"], c["k"], c["v"], hdp)
                ref = dense_attention_reference(c["q"], c["k"], c["v"],
                                                causal=True)
                cosines.append(common.cosine(out, ref))
                sps.append(float(st.block_sparsity))
            rows.append({
                "rho_b": rho, "approx": approx,
                "block_sparsity": round(float(np.mean(sps)), 4),
                "agreement": round(ag, 4),
                "attn_cosine": round(float(np.mean(cosines)), 4)})
    return rows


def main(quick: bool = False) -> List[Dict]:
    out = []
    for scale in ("tiny", "base"):
        rows = run(scale, n_eval=1 if quick else 2,
                   train_steps=200 if quick else 400)
        print(f"# approximation (Fig.9 analog) scale={scale}")
        print("rho_b,approx,block_sparsity,agreement,attn_cosine")
        for r in rows:
            print(f"{r['rho_b']},{r['approx']},{r['block_sparsity']},"
                  f"{r['agreement']},{r['attn_cosine']}")
        out.extend({**r, "scale": scale} for r in rows)
    return out


if __name__ == "__main__":
    main()
