"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

| name           | paper artifact                 |
|----------------|--------------------------------|
| block_pruning  | Fig. 7  (HDP vs Top-K)         |
| head_pruning   | Fig. 8 + Fig. 11 (SpAtten)     |
| approximation  | Fig. 9                         |
| net_pruning    | Fig. 10                        |
| kernels        | kernel correctness + FUM bytes |
| roofline       | dry-run roofline table (§g)    |
| serving        | end-to-end engine throughput   |
| serving_paged  | paged vs dense KV cache A/B    |

Accuracy is proxied by top-1 next-token agreement vs the dense model on
held-out synthetic data (no GLUE checkpoints offline — substitution
documented in DESIGN.md §1). All output is CSV-ish text; bench_output.txt
is the canonical artifact.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def bench_serving(quick: bool = False, backend: str = "auto"):
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b", "granite-8b"):
        for no_hdp in (False, True):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "4" if quick else "8",
                 "--max-new", "4" if quick else "6", "--backend", backend]
                + (["--no-hdp"] if no_hdp else []))
            # every row records the RESOLVED backend per phase
            # (attn_prefill / attn_decode), so A/B rows stay attributable
            # when auto-selection or fallback changes
            out = serve.run(args)
            rows.append({"arch": arch, "hdp": not no_hdp, **out})
    print("# serving (reduced configs, continuous batching)")
    hdr = list(rows[0].keys())
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_paged(quick: bool = False, backend: str = "auto"):
    """Paged vs dense cache backend A/B: decode tok/s + resident cache bytes.

    With HDP enabled the paged backend stores the int8 scout copy but
    allocates pages per request (prompt + budget) instead of max_len per
    slot, and its decode gathers only scout-surviving pages — cache bytes
    must come out <= the dense per-slot layout, tokens identical (the
    write-time scout pins calib="none"; see DESIGN notes in
    serving/engine.py).
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b", "granite-8b"):
        for layout in ("paged", "dense"):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "4" if quick else "8",
                 "--max-new", "4" if quick else "6", "--backend", backend,
                 "--layout", layout, "--calib", "none"])
            out = serve.run(args)
            row = {"arch": arch, **out}
            row["backend"] = layout  # the A/B independent variable
            rows.append(row)
    print("# serving paged-vs-dense (reduced configs, HDP on, calib=none)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    by_arch = {}
    for r in rows:
        by_arch.setdefault(r["arch"], {})[r["backend"]] = r
    for arch, pair in by_arch.items():
        p, d = pair["paged"], pair["dense"]
        assert p["cache_bytes"] <= d["cache_bytes"], \
            f"{arch}: paged cache {p['cache_bytes']} > dense {d['cache_bytes']}"
        print(f"## {arch}: paged cache {p['cache_bytes']}B <= "
              f"dense {d['cache_bytes']}B "
              f"({1 - p['cache_bytes'] / max(d['cache_bytes'], 1):.0%} less), "
              f"page_sparsity {p['page_sparsity']}")
    return rows


BENCHES = {}


def _register():
    from benchmarks import (approximation, block_pruning, decode_roofline,
                            head_pruning, kernels_bench, net_pruning,
                            roofline_table)
    BENCHES.update({
        "block_pruning": block_pruning.main,
        "head_pruning": head_pruning.main,
        "approximation": approximation.main,
        "net_pruning": net_pruning.main,
        "kernels": kernels_bench.main,
        "roofline": roofline_table.main,
        "decode_roofline": decode_roofline.main,
        "serving": bench_serving,
        "serving_paged": bench_serving_paged,
    })


#: benches that accept an attention-backend selection (--backend)
_BACKEND_AWARE = ("serving", "serving_paged")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps / fewer eval batches")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--backend", default="auto",
                    help="attention backend name/tag for the serving "
                         "benches; the resolved (post-fallback) backend is "
                         "recorded per output row")
    args = ap.parse_args(argv)
    _register()
    names = list(BENCHES) if not args.only else args.only.split(",")
    failures = []
    for name in names:
        if name not in BENCHES:
            print(f"!! unknown benchmark {name}; have {sorted(BENCHES)}")
            failures.append(name)
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        kw = {"quick": args.quick}
        if name in _BACKEND_AWARE:
            kw["backend"] = args.backend
        try:
            BENCHES[name](**kw)
            print(f"===== {name} done in {time.time()-t0:.0f}s =====",
                  flush=True)
        except Exception:  # noqa: BLE001 — keep the harness going
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
