"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
        [--json [PATH]] [--horizon H]

| name           | paper artifact                 |
|----------------|--------------------------------|
| block_pruning  | Fig. 7  (HDP vs Top-K)         |
| head_pruning   | Fig. 8 + Fig. 11 (SpAtten)     |
| approximation  | Fig. 9                         |
| net_pruning    | Fig. 10                        |
| kernels        | kernel correctness + FUM bytes |
| roofline       | dry-run roofline table (§g)    |
| serving        | end-to-end engine throughput   |
| serving_paged  | paged vs dense KV cache A/B    |
| serving_prefix | prefix-cache hit vs cold A/B   |
| serving_spec   | speculative decode vs H=4 A/B  |
| serving_stream | stream scheduler vs static/solo|
| serving_autotune | cost policy vs static A/B + crossover sweep |
| serving_kvquant | int8/fp8_v KV pool vs fp32 oracle A/B |
| serving_tp     | tensor-parallel TP=1/2/4 sharded-pool A/B |
| serving_chaos  | goodput under injected faults vs clean A/B |

Accuracy is proxied by top-1 next-token agreement vs the dense model on
held-out synthetic data (no GLUE checkpoints offline — substitution
documented in DESIGN.md §1). All output is CSV-ish text; bench_output.txt
is the canonical artifact. ``--json`` additionally persists the serving
rows to BENCH_serving.json at the repo root (preserving the recorded
pre-existing ``baseline`` block) so the decode-path perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

#: default artifact path for --json (repo root, next to this package)
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


def bench_serving(quick: bool = False, backend: str = "auto",
                  horizon: int = 4):
    """End-to-end engine throughput, fused-decode-loop A/B included.

    Rows come in pairs per arch: decode_horizon=1 (per-token stepping,
    the pre-fusion hot path) and decode_horizon=``horizon`` — same
    engine, same tokens, one host sync per horizon."""
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b", "granite-8b"):
        for no_hdp in (False, True):
            for h in dict.fromkeys((1, horizon)):
                # max-new 24 (vs the functional benches' 6) so decode
                # spans enough steps for a stable steady-state tok/s
                args = serve.build_parser().parse_args(
                    ["--arch", arch, "--requests", "4" if quick else "8",
                     "--max-new", "8" if quick else "24",
                     "--backend", backend, "--decode-horizon", str(h),
                     "--warmup"]
                    + (["--no-hdp"] if no_hdp else []))
                # every row records the RESOLVED backend per phase
                # (attn_prefill / attn_decode), so A/B rows stay
                # attributable when auto-selection or fallback changes
                out = serve.run(args)
                rows.append({"arch": arch, "hdp": not no_hdp, **out})
    print("# serving (reduced configs, continuous batching, horizon A/B)")
    hdr = list(rows[0].keys())
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_paged(quick: bool = False, backend: str = "auto"):
    """Paged vs dense cache backend A/B: decode tok/s + resident cache bytes.

    With HDP enabled the paged backend stores the int8 scout copy but
    allocates pages per request (prompt + budget) instead of max_len per
    slot, and its decode gathers only scout-surviving pages — cache bytes
    must come out <= the dense per-slot layout, tokens identical (the
    write-time scout pins calib="none"; see DESIGN notes in
    serving/engine.py).
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b", "granite-8b"):
        for layout in ("paged", "dense"):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "4" if quick else "8",
                 "--max-new", "4" if quick else "6", "--backend", backend,
                 "--layout", layout, "--calib", "none", "--warmup"])
            out = serve.run(args)
            row = {"arch": arch, **out}
            row["backend"] = layout  # the A/B independent variable
            rows.append(row)
    print("# serving paged-vs-dense (reduced configs, HDP on, calib=none)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    by_arch = {}
    for r in rows:
        by_arch.setdefault(r["arch"], {})[r["backend"]] = r
    for arch, pair in by_arch.items():
        p, d = pair["paged"], pair["dense"]
        assert p["cache_bytes"] <= d["cache_bytes"], \
            f"{arch}: paged cache {p['cache_bytes']} > dense {d['cache_bytes']}"
        print(f"## {arch}: paged cache {p['cache_bytes']}B <= "
              f"dense {d['cache_bytes']}B "
              f"({1 - p['cache_bytes'] / max(d['cache_bytes'], 1):.0%} less), "
              f"page_sparsity {p['page_sparsity']}")
    return rows


def bench_serving_prefix(quick: bool = False, backend: str = "auto"):
    """Shared-prefix A/B: prefix-cache hits vs cold prefill.

    The workload is 8 requests whose prompts share a 256-token random
    prefix (distinct random tails), served twice through the same paged
    engine configuration: ``--prefix-cache`` (suffix-only prefill through
    the radix tree) and ``--no-prefix-cache`` (every prompt prefilled
    from scratch). Asserts the acceptance contract: byte-identical
    generated tokens (tokens_fp), prefill wall time reduced, and a page
    high-water mark that reflects sharing (shared prefix pages counted
    once instead of once per slot).
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        pair = {}
        for prefix_on in (True, False):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "8",
                 "--max-new", "4" if quick else "6",
                 "--max-len", "384", "--backend", backend, "--warmup",
                 "--shared-prefix", "256",
                 "--prefix-cache" if prefix_on else "--no-prefix-cache"])
            out = serve.run(args)
            row = {"arch": arch, "prefix": prefix_on, **out}
            row["backend"] = "prefix" if prefix_on else "cold"  # A/B variable
            rows.append(row)
            pair[prefix_on] = row
        hot, cold = pair[True], pair[False]
        assert hot["tokens_fp"] == cold["tokens_fp"], \
            f"{arch}: prefix-cache hits changed the generated tokens"
        assert hot["prefix_hits"] > 0, f"{arch}: workload produced no hits"
        assert hot["pages_peak"] < cold["pages_peak"], \
            f"{arch}: page peak {hot['pages_peak']} shows no sharing " \
            f"(cold {cold['pages_peak']})"
        # prefill-FLOPs proxy: tokens actually run through prefill
        # forwards (deterministic — wall time is reported, not asserted,
        # because it flakes on loaded CI runners)
        assert hot["prefill_tokens"] < cold["prefill_tokens"], \
            f"{arch}: prefill tokens {hot['prefill_tokens']} not below " \
            f"cold {cold['prefill_tokens']}"
        print(f"## {arch}: prefill {hot['prefill_tokens']} tokens vs cold "
              f"{cold['prefill_tokens']} "
              f"({1 - hot['prefill_tokens'] / max(cold['prefill_tokens'], 1):.0%} less), "
              f"wall {hot['prefill_s_total']}s vs {cold['prefill_s_total']}s, "
              f"pages_peak {hot['pages_peak']} vs {cold['pages_peak']}, "
              f"{hot['prefix_hits']} hits / {hot['prefix_hit_tokens']} "
              f"tokens, tokens byte-identical")
    print("# serving shared-prefix A/B (8 requests, 256-token shared prefix)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_spec(quick: bool = False, backend: str = "auto"):
    """Self-speculative decode A/B: draft+verify rounds vs the fused H=4 loop.

    The workload is long-context decode (8 requests over a 256-token
    shared prefix, max_len 384) — the regime the draft's int8-scout
    bandwidth win targets: per round, ``draft_len - 1`` draft steps read
    only the two int8 scout copies plus surviving pages' V, and ONE
    multi-query verify reads the full-precision pool once for the whole
    round. Asserts the acceptance contract: byte-identical generated
    tokens (tokens_fp) vs the horizon-4 baseline at whatever acceptance
    rate the draft achieves, with at least one accepted draft token.
    Tok/s and the achieved acceptance rate are recorded per row (wall
    time is reported, not asserted — it flakes on loaded CI runners;
    median-of-3 steady-state runs on this workload measure ~1.2-1.4x
    over H=4 at draft_len 12, acceptance 1.0).
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        pair = {}
        for spec_on in (True, False):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "8",
                 "--max-new", "8" if quick else "24",
                 "--max-len", "384", "--shared-prefix", "256",
                 "--backend", backend, "--warmup"]
                + (["--spec-decode", "--draft-len", "12"] if spec_on
                   else ["--no-spec-decode", "--decode-horizon", "4"]))
            out = serve.run(args)
            row = {"arch": arch, **out}
            row["backend"] = "spec" if spec_on else "h4"   # A/B variable
            rows.append(row)
            pair[spec_on] = row
        sp, h4 = pair[True], pair[False]
        assert sp["tokens_fp"] == h4["tokens_fp"], \
            f"{arch}: speculative decode changed the generated tokens"
        assert sp["spec_rounds"] > 0 and sp["draft_tokens"] > 0, \
            f"{arch}: no speculative rounds ran"
        assert sp["accepted_tokens"] > 0, \
            f"{arch}: the draft never proposed an accepted token"
        speedup = sp["decode_tok_s"] / max(h4["decode_tok_s"], 1e-9)
        print(f"## {arch}: spec-decode {sp['decode_tok_s']} tok/s vs "
              f"H=4 {h4['decode_tok_s']} (x{speedup:.2f}) at acceptance "
              f"{sp['acceptance_rate']} "
              f"({sp['accepted_tokens']}/{sp['draft_tokens']} drafts), "
              f"tokens byte-identical")
    print("# serving speculative-decode A/B (8 requests, 256-token shared "
          "prefix, draft_len 12)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_stream(quick: bool = False, backend: str = "auto"):
    """Continuous-batching A/B: stream scheduler vs static waves vs solo.

    One seeded prompt set served three ways: ``stream`` — the scheduler
    with a Poisson arrival process (requests submitted mid-run, token-
    budget admission, slots recycled in flight); ``static`` — the fixed-
    wave engine, everything submitted up front; ``solo`` — max_batch=1,
    every request alone (the isolation reference). Asserts the
    acceptance contract: queued requests really were admitted into slots
    vacated mid-run (``sched_recycled`` > 0 — with 2 slots and 3x more
    requests, admission past the first wave happens between decode
    rounds, not at a drain barrier), per-request generated tokens
    byte-identical across all three legs (``tokens_fp``: scheduling
    reorders admission, never compute), and TTFT / TPOT / queue-depth
    stats recorded on the stream row.
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        base = ["--arch", arch, "--requests", "6" if quick else "12",
                "--max-new", "4" if quick else "8", "--max-batch", "2",
                "--backend", backend, "--seed", "3", "--warmup"]
        legs = {}
        for name, extra in (
                ("stream", ["--stream-sched", "--arrival-rate", "0.5"]),
                ("static", []),
                ("solo", [])):
            argv = list(base)
            if name == "solo":
                argv[argv.index("--max-batch") + 1] = "1"
            out = serve.run(serve.build_parser().parse_args(argv + extra))
            row = {"arch": arch, **out}
            row["backend"] = name              # the A/B independent variable
            rows.append(row)
            legs[name] = row
        st, fx, so = legs["stream"], legs["static"], legs["solo"]
        assert st["tokens_fp"] == fx["tokens_fp"], \
            f"{arch}: stream scheduling changed the generated tokens"
        assert st["tokens_fp"] == so["tokens_fp"], \
            f"{arch}: stream tokens differ from per-request isolation"
        assert st["sched_recycled"] > 0, \
            f"{arch}: no request was admitted into a mid-run vacated slot"
        assert st["ttft_s_mean"] > 0 and st["tpot_s_mean"] >= 0 \
            and st["queue_depth_peak"] > 0, \
            f"{arch}: stream row missing TTFT/TPOT/queue-depth stats"
        print(f"## {arch}: stream {st['decode_tok_s']} tok/s vs static "
              f"{fx['decode_tok_s']} vs solo {so['decode_tok_s']}, "
              f"{st['sched_recycled']} mid-run slot recycles, TTFT mean "
              f"{st['ttft_s_mean']}s / p95 {st['ttft_s_p95']}s, TPOT "
              f"{st['tpot_s_mean']}s, queue depth peak "
              f"{st['queue_depth_peak']}, tokens byte-identical x3")
    print("# serving stream-scheduler A/B (Poisson arrivals, 2 slots)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_autotune(quick: bool = False, backend: str = "auto"):
    """Cost-driven backend selection A/B plus the predicted crossover sweep.

    Per arch x hdp config, the same seeded workload runs once under
    ``--policy static`` (registry priority order) and once under
    ``--policy cost`` (the repro.autotune cost model ranks the auto
    candidates under the detected hardware profile, sharpened by the
    measured sparsity counters). Asserts the subsystem's acceptance
    contract: generated tokens byte-identical across policies (cost only
    selects among backends supporting the same call semantics), and —
    whenever the cost policy resolves a DIFFERENT decode backend than
    the static order — that its pick's decode tok/s stays within a noise
    tolerance of the static pick. When both policies resolve the same
    backend the compiled programs are identical, so the ratio is
    reported but not gated (a handful of quick decode steps cannot
    support a perf assertion). Tuner cache counters are recorded per
    cost row.

    Also records the predicted kv_len x page-sparsity crossover table
    (paged-HDP decode vs dense attention step time) — the motivating
    tradeoff of the whole subsystem — as ``backend="crossover"`` rows
    (no decode_tok_s, so the regression gate skips them by design).
    """
    from repro.autotune import CallSig, crossover_table, reset_default_tuner
    from repro.launch import serve
    from repro.roofline.hardware import detect_profile

    rows = []
    tol = 0.5 if quick else 0.35
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        for no_hdp in (False, True):
            pair = {}
            for policy in ("static", "cost"):
                reset_default_tuner()   # each leg tunes from cold
                args = serve.build_parser().parse_args(
                    ["--arch", arch, "--requests", "4" if quick else "8",
                     "--max-new", "8" if quick else "24",
                     "--backend", backend, "--policy", policy, "--warmup"]
                    + (["--no-hdp"] if no_hdp else []))
                out = serve.run(args)
                row = {"arch": arch, "hdp": not no_hdp, **out}
                row["backend"] = policy   # the A/B independent variable
                rows.append(row)
                pair[policy] = row
            st, co = pair["static"], pair["cost"]
            assert co["tokens_fp"] == st["tokens_fp"], \
                f"{arch} hdp={not no_hdp}: cost policy changed the tokens"
            if co["attn_decode"] != st["attn_decode"]:
                # cost picked a different program — THAT choice must not
                # be a regression. When the picks agree the compiled
                # programs are identical and any tok/s delta is host
                # noise (these quick runs decode a handful of steps), so
                # the ratio is reported, not gated.
                assert co["decode_tok_s"] >= st["decode_tok_s"] * (1 - tol), \
                    (f"{arch} hdp={not no_hdp}: cost-picked "
                     f"{co['attn_decode']} decode {co['decode_tok_s']} "
                     f"tok/s fell more than {tol:.0%} below static "
                     f"{st['attn_decode']} {st['decode_tok_s']}")
            print(f"## {arch} hdp={not no_hdp}: cost "
                  f"{co['decode_tok_s']} tok/s ({co['attn_decode']}) vs "
                  f"static {st['decode_tok_s']} ({st['attn_decode']}), "
                  f"tuner misses {co.get('tuner_misses', 0)} probes "
                  f"{co.get('tuner_probes', 0)}, tokens byte-identical")
    print("# serving cost-policy A/B (auto candidates ranked by the "
          "analytic cost model, measured-sparsity sharpened)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))

    # predicted crossover sweep: where sparsity x kv_len starts paying for
    # the sparse pipeline's per-op overhead (model-free — pure predictor)
    hw = detect_profile()
    sig = CallSig(mode="decode", layout="paged", batch=4, n_kv_heads=2,
                  group=6, sq=1, hd=64, kv_len=0, page_size=16, hdp=True,
                  per_slot=True, kv_itemsize=1)  # the int8 default pool
    print(f"# predicted paged-HDP vs dense crossover ({hw.name})")
    print("kv_len,page_sparsity,t_hdp_s,t_dense_s,winner")
    for c in crossover_table(sig, hw, kv_lens=(128, 512, 2048, 8192),
                             page_sparsities=(0.0, 0.25, 0.5, 0.75)):
        print(f"{c['kv_len']},{c['page_sparsity']},{c['t_hdp_s']:.3e},"
              f"{c['t_dense_s']:.3e},{c['winner']}")
        rows.append({"arch": "predictor", "hdp": True,
                     "backend": "crossover", "hw": hw.name, **c})
    return rows


def bench_serving_kvquant(quick: bool = False, backend: str = "auto"):
    """Quantized KV pool A/B: int8 / fp8_v storage vs the fp32 oracle.

    Long-context shared-prefix workload (8 requests over a 256-token
    shared prefix, max_len 384 — the resident-cache-bound regime the
    quantized pool targets), served once per storage format through
    otherwise identical engines. Asserts the acceptance contract:

    * resident pool bytes-per-token of the quantized formats come out
      <= 0.35x the fp32 oracle's (codes + per-page scales, measured
      from the engine's dtype-aware footprint accounting);
    * decode tok/s of the int8 leg stays within a noise tolerance of
      the fp32 oracle (the in-register dequant must not cost the
      gather path its throughput);
    * greedy-logit drift under the documented gate: the same prompts
      pushed through the prefill forward under each storage format
      produce finite logits whose max abs deviation from the fp32
      leg stays below 0.9x the fp32 logit absmax. On the random-init
      reduced configs served offline the logit range is tiny and the
      top-1 token flips at perturbations far below the quantization
      step, so the gate is a deterministic sanity bound that catches
      implementation breakage (mis-applied scales, poison leaking
      into live pages) rather than an ML-quality claim; top-1
      agreement vs the oracle is reported per row. Token identity is
      therefore NOT asserted across storage formats — identity under
      any FIXED format is pinned by the serving suites and
      tests/test_kv_quant.py.
    """
    import numpy as np

    from repro.attention import AttnSpec
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.launch import serve
    from repro.models import registry
    from repro.serving import Engine

    rows = []
    tol = 0.5 if quick else 0.35
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        legs = {}
        for dt in ("int8", "fp8_v", "fp32"):
            args = serve.build_parser().parse_args(
                ["--arch", arch, "--requests", "8",
                 "--max-new", "4" if quick else "8",
                 "--max-len", "384", "--shared-prefix", "256",
                 "--backend", backend, "--kv-dtype", dt, "--warmup"])
            out = serve.run(args)
            row = {"arch": arch, **out}
            rows.append(row)
            legs[dt] = row

        # resident footprint: the tentpole claim of the quantized pool
        fp32 = legs["fp32"]
        for dt in ("int8", "fp8_v"):
            ratio = legs[dt]["cache_bytes_per_token"] \
                / fp32["cache_bytes_per_token"]
            assert ratio <= 0.35, \
                (f"{arch}: {dt} pool {legs[dt]['cache_bytes_per_token']} "
                 f"B/token is x{ratio:.2f} of fp32 "
                 f"{fp32['cache_bytes_per_token']} (> 0.35 tolerated)")
        assert legs["int8"]["decode_tok_s"] \
            >= fp32["decode_tok_s"] * (1 - tol), \
            (f"{arch}: int8 decode {legs['int8']['decode_tok_s']} tok/s "
             f"fell more than {tol:.0%} below the fp32 oracle "
             f"{fp32['decode_tok_s']}")

        # greedy-logit drift probe: one prefill forward per format over
        # the same seeded long-context prompts (this is exactly the
        # computation the paged engines run at prefill time — the
        # round-trip gates on AttnSpec.kv_dtype alone)
        cfg = reduced(get_config(arch))
        eng = Engine(cfg, max_batch=1, max_len=32)     # params only
        rng = np.random.default_rng(0)
        toks = rng.integers(1, cfg.vocab_size, size=(4, 288))
        logit = {}
        for dt in ("fp32", "int8", "fp8_v"):
            cache = registry.init_cache(cfg, 4, max_len=288)
            lg, _, _ = registry.apply_prefill(
                cfg, eng.params, {"tokens": toks}, cache,
                attn=AttnSpec(kv_dtype=dt))
            logit[dt] = np.asarray(lg[:, -1])
        ref = logit["fp32"]
        assert np.isfinite(ref).all(), f"{arch}: fp32 logits not finite"
        gate = 0.9 * float(np.abs(ref).max())
        for dt in ("int8", "fp8_v"):
            assert np.isfinite(logit[dt]).all(), \
                f"{arch}: {dt} prefill logits not finite (poison leak?)"
            drift = float(np.abs(logit[dt] - ref).max())
            agree = float((logit[dt].argmax(-1) == ref.argmax(-1)).mean())
            assert drift <= gate, \
                (f"{arch}: {dt} greedy-logit drift {drift:.4f} exceeds "
                 f"the documented gate {gate:.4f} (0.9x fp32 absmax)")
            legs[dt]["logit_drift"] = round(drift, 4)
            legs[dt]["logit_drift_gate"] = round(gate, 4)
            legs[dt]["oracle_top1_agree"] = round(agree, 4)
            print(f"## {arch} {dt}: {legs[dt]['cache_bytes_per_token']} "
                  f"B/token vs fp32 {fp32['cache_bytes_per_token']} "
                  f"(x{legs[dt]['cache_bytes_per_token'] / fp32['cache_bytes_per_token']:.2f}), "
                  f"decode {legs[dt]['decode_tok_s']} tok/s vs "
                  f"{fp32['decode_tok_s']}, logit drift {drift:.4f} "
                  f"(gate {gate:.4f}), top-1 agree {agree:.2f}")
    print("# serving kv-quant A/B (8 requests, 256-token shared prefix, "
          "int8/fp8_v pool vs fp32 oracle)")
    hdr = [h for h in rows[-1] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


def bench_serving_tp(quick: bool = False, backend: str = "auto"):
    """Tensor-parallel serving A/B: TP=1 vs 2 vs 4 over the sharded pool.

    The workload is the stream arch with MHA head counts that divide by
    4 (olmoe-1b-7b reduced: 4 KV heads), served through otherwise
    identical paged engines at ``--tp 1/2/4``. Mesh legs beyond the
    available device count are skipped with a loud note (CPU hosts need
    XLA_FLAGS=--xla_force_host_platform_device_count=4 exported BEFORE
    the process starts — jax fixes the device count at backend init).
    Asserts the acceptance contract per sharded leg vs TP=1:

    * byte-identical generated tokens (``tokens_fp``) — the decode
      all-gather concatenates exact per-shard head outputs, it never
      float-reduces, so TP is a pure layout transform;
    * per-shard resident pool bytes == total pool bytes / TP (the pool
      is sharded along kv-heads, never replicated);
    * decode step time within a generous noise margin of the TP=1 leg
      (host-CPU meshes simulate devices on shared cores, so the gate
      only catches collapses, not the real-accelerator scaling claim).
    """
    import jax

    from repro.launch import serve

    ndev = len(jax.devices())
    arch = "olmoe-1b-7b"   # reduced: MHA, 4 kv heads -> tp in {1, 2, 4}
    degrees = [tp for tp in (1, 2, 4) if tp <= ndev]
    if len(degrees) < 3:
        print(f"!! serving_tp: only {ndev} jax device(s) visible; running "
              f"tp={degrees} and skipping the rest (export XLA_FLAGS="
              f"--xla_force_host_platform_device_count=4 for the full A/B)")
    rows, legs = [], {}
    for tp in degrees:
        args = serve.build_parser().parse_args(
            ["--arch", arch, "--requests", "4" if quick else "8",
             "--max-new", "8" if quick else "24",
             "--layout", "paged", "--backend", backend,
             "--tp", str(tp), "--warmup"])
        out = serve.run(args)
        row = {"arch": arch, "hdp": True, **out}
        row["backend"] = f"tp{tp}"         # the A/B independent variable
        rows.append(row)
        legs[tp] = row
    base = legs[1]
    for tp in degrees[1:]:
        r = legs[tp]
        assert r["tokens_fp"] == base["tokens_fp"], \
            f"{arch}: tp={tp} changed the generated tokens"
        assert r["cache_bytes_pool_per_shard"] * tp \
            == r["cache_bytes_pool"], \
            (f"{arch}: tp={tp} per-shard pool "
             f"{r['cache_bytes_pool_per_shard']}B x{tp} != total "
             f"{r['cache_bytes_pool']}B — pool not evenly sharded")
        if r.get("meas_decode_step_s") and base.get("meas_decode_step_s"):
            # host-CPU meshes time-slice the simulated devices onto the
            # same cores, so sharded steps measure slower, not faster —
            # the gate is a collapse-catcher (shard_map retrace loops,
            # accidental full-pool gathers), not a scaling assertion
            assert r["meas_decode_step_s"] \
                <= base["meas_decode_step_s"] * 5.0, \
                (f"{arch}: tp={tp} decode step "
                 f"{r['meas_decode_step_s']}s collapsed vs tp=1 "
                 f"{base['meas_decode_step_s']}s (>5x)")
        print(f"## {arch} tp={tp}: {r['decode_tok_s']} tok/s vs tp=1 "
              f"{base['decode_tok_s']}, per-shard pool "
              f"{r['cache_bytes_pool_per_shard']}B = "
              f"{r['cache_bytes_pool']}B / {tp}, mesh {r.get('mesh')}, "
              f"tokens byte-identical")
    print("# serving tensor-parallel A/B (sharded page pool, head-axis "
          "shard_map decode)")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


#: the deterministic chaos plan driven through --fault-plan in the
#: serving_chaos A/B: a slow step, an injected pool exhaustion (deferred
#: admission), a per-slot NaN tripwire on uid 1, then replica 0 killed.
CHAOS_PLAN = "slow@0:s=0.002;exhaust@1;nan@2:uid=1;kill@3:replica=0"


def bench_serving_chaos(quick: bool = False, backend: str = "auto"):
    """Goodput-under-faults A/B: clean fleet vs deterministic chaos.

    The same seeded workload runs twice on a dp=2 stream-scheduled
    fleet: ``clean`` — no faults; ``chaos`` — ``CHAOS_PLAN`` injected
    (slow step, pool exhaustion, a NaN-poisoned slot, replica 0 killed
    mid-run). Goodput is tokens of *ok* requests per decode second.
    Asserts the fault-tolerance acceptance contract: no request is lost
    (every uid gets a typed Result — failed over, shed, or errored, but
    never silently dropped), exactly the NaN-poisoned request fails
    while its batchmates complete in full, every scheduled fault event
    fired, and replica 0 is reported dead with its work failed over.
    """
    from repro.launch import serve

    rows = []
    for arch in ("qwen2-1.5b",) if quick else ("qwen2-1.5b", "granite-8b"):
        base = ["--arch", arch, "--requests", "6" if quick else "10",
                "--max-new", "4" if quick else "8", "--max-batch", "2",
                "--backend", backend, "--seed", "3", "--dp", "2",
                "--stream-sched", "--warmup"]
        legs = {}
        for name, extra in (("clean", []),
                            ("chaos", ["--fault-plan", CHAOS_PLAN])):
            out = serve.run(serve.build_parser().parse_args(base + extra))
            row = {"arch": arch, **out}
            row["backend"] = name          # the A/B independent variable
            row["faults"] = out.get("fault_plan") or "none"
            if out.get("decode_tok_s") and out["requests"]:
                ok_frac = out["requests_ok"] / out["requests"]
                row["goodput_tok_s"] = round(
                    out["decode_tok_s"] * ok_frac, 2)
            rows.append(row)
            legs[name] = row
        cl, ch = legs["clean"], legs["chaos"]
        assert cl["requests_ok"] == cl["requests"] \
            and cl["requests_lost"] == 0, \
            f"{arch}: clean leg dropped requests: {cl}"
        assert ch["requests_lost"] == 0, \
            (f"{arch}: chaos leg lost {ch['requests_lost']} request(s) — "
             "failover/shed must always leave a typed Result")
        assert ch["requests_failed"] == 1 \
            and ch["requests_ok"] == ch["requests"] - 1, \
            (f"{arch}: chaos leg expected exactly the NaN-poisoned "
             f"request to fail: ok={ch['requests_ok']} "
             f"failed={ch['requests_failed']} of {ch['requests']}")
        assert ch["faults_fired"] == len(CHAOS_PLAN.split(";")), \
            (f"{arch}: only {ch['faults_fired']} of the scheduled fault "
             "events fired — the plan never fully exercised the fleet")
        assert ch["replica_health"] == ["dead", "up"] \
            and ch["failovers"] == 1 and ch["requests_failed_over"] > 0, \
            (f"{arch}: replica-0 kill not reflected: "
             f"health={ch['replica_health']} failovers={ch['failovers']} "
             f"failed_over={ch['requests_failed_over']}")
        print(f"## {arch}: goodput {ch.get('goodput_tok_s')} tok/s under "
              f"chaos vs {cl.get('goodput_tok_s')} clean, "
              f"{ch['requests_ok']}/{ch['requests']} ok, "
              f"{ch['requests_failed_over']} failed over after replica-0 "
              f"kill, {ch['faults_fired']} fault events fired")
    print("# serving fault-tolerance A/B (dp=2 stream fleet, "
          f"plan {CHAOS_PLAN})")
    hdr = [h for h in rows[0] if h != "requests"]
    print(",".join(str(h) for h in hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


BENCHES = {}


def _register():
    from benchmarks import (approximation, block_pruning, decode_roofline,
                            head_pruning, kernels_bench, net_pruning,
                            roofline_table)
    BENCHES.update({
        "block_pruning": block_pruning.main,
        "head_pruning": head_pruning.main,
        "approximation": approximation.main,
        "net_pruning": net_pruning.main,
        "kernels": kernels_bench.main,
        "roofline": roofline_table.main,
        "decode_roofline": decode_roofline.main,
        "serving": bench_serving,
        "serving_paged": bench_serving_paged,
        "serving_prefix": bench_serving_prefix,
        "serving_spec": bench_serving_spec,
        "serving_stream": bench_serving_stream,
        "serving_autotune": bench_serving_autotune,
        "serving_kvquant": bench_serving_kvquant,
        "serving_tp": bench_serving_tp,
        "serving_chaos": bench_serving_chaos,
    })


#: benches that accept an attention-backend selection (--backend)
_BACKEND_AWARE = ("serving", "serving_paged", "serving_prefix",
                  "serving_spec", "serving_stream", "serving_autotune",
                  "serving_kvquant", "serving_tp", "serving_chaos")


def write_bench_json(path: str, results: dict, *, quick: bool,
                     horizon: int) -> None:
    """Persist serving rows to ``path``, preserving the ``baseline`` block.

    The file tracks the decode-path perf trajectory across PRs:
    ``baseline`` is written once (the oldest recorded run, kept verbatim
    on every later write) and ``current`` is replaced per run. Rows carry
    decode_tok_s, decode_s_per_tok, cache_bytes and the achieved
    block/head/page sparsity per arch x hdp x horizon cell.
    """
    rows = []
    for name in _BACKEND_AWARE:
        for r in results.get(name) or []:
            row = {"bench": name, **r}
            if r.get("decode_tok_s"):
                row["decode_s_per_tok"] = round(1.0 / r["decode_tok_s"], 6)
            rows.append(row)
    if not rows:
        print(f"!! --json: no serving rows collected; {path} not written")
        return
    prev = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = {}
    current = {"quick": quick, "decode_horizon": horizon, "rows": rows}
    data = {"baseline": prev.get("baseline") or current, "current": current}
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    if data["baseline"].get("quick") != quick:
        print("## baseline was recorded at a different --quick setting; "
              "tok/s comparison skipped")
    else:
        base_rows = data["baseline"].get("rows", [])

        def key(r):  # backend disambiguates serving_paged's layout A/B rows
            return (r.get("arch"), r.get("hdp"), r.get("bench"),
                    r.get("backend"))

        by_h = {}
        for r in rows:
            for b in base_rows:
                if key(b) == key(r) and b.get("decode_tok_s") \
                        and r.get("decode_tok_s"):
                    # baseline rows are per-token (horizon 1); grouping
                    # current rows by their horizon makes the fused-loop
                    # speedup vs the per-token baseline explicit
                    by_h.setdefault(r.get("decode_horizon", 1), []).append(
                        r["decode_tok_s"] / b["decode_tok_s"])
                    break
        for h in sorted(by_h):
            pairs = by_h[h]
            print(f"## decode tok/s vs baseline (horizon={h}): "
                  f"x{sum(pairs)/len(pairs):.2f} "
                  f"(mean over {len(pairs)} comparable rows)")
    print(f"## wrote {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps / fewer eval batches")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--backend", default="auto",
                    help="attention backend name/tag for the serving "
                         "benches; the resolved (post-fallback) backend is "
                         "recorded per output row")
    ap.add_argument("--horizon", type=int, default=4,
                    help="fused decode horizon for the serving benches "
                         "(each arch also records a horizon=1 row for the "
                         "per-token A/B)")
    ap.add_argument("--json", nargs="?", const=BENCH_JSON, default=None,
                    metavar="PATH",
                    help="write serving rows to PATH (default "
                         "BENCH_serving.json at the repo root), preserving "
                         "the recorded baseline block")
    args = ap.parse_args(argv)
    _register()
    names = list(BENCHES) if not args.only else args.only.split(",")
    failures = []
    results = {}
    for name in names:
        if name not in BENCHES:
            print(f"!! unknown benchmark {name}; have {sorted(BENCHES)}")
            failures.append(name)
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        kw = {"quick": args.quick}
        if name in _BACKEND_AWARE:
            kw["backend"] = args.backend
        if name == "serving":
            kw["horizon"] = args.horizon
        try:
            results[name] = BENCHES[name](**kw)
            print(f"===== {name} done in {time.time()-t0:.0f}s =====",
                  flush=True)
        except Exception:  # noqa: BLE001 — keep the harness going
            failures.append(name)
            traceback.print_exc()
    if args.json:
        write_bench_json(args.json, results, quick=args.quick,
                         horizon=args.horizon)
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
