"""Kernel microbenchmark: correctness + FUM memory-traffic accounting.

No TPU in this container, so kernels run in interpret mode: the benchmark
verifies (a) allclose vs the pure-jnp oracle across shapes, and (b) the
*structural* memory win of Fetch-Upon-Mask — HBM bytes that the
block-sparse kernel's BlockSpecs fetch vs the dense flash kernel, at the
sparsity level the scout actually produced. On hardware (b) is the
bandwidth saving; the byte accounting below is exact because the grid +
BlockSpec decide DMA traffic deterministically.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import HDPConfig
from repro.core.hdp import hdp_attention
from repro.kernels import ops
from repro.kernels import ref as kref

SHAPES = ((1, 2, 256, 64), (2, 4, 128, 64))


def flash_bytes(B, H, Sq, Sk, hd, bq, bk, itemsize=4) -> int:
    """Dense flash: Q once, K/V once per q-block (no reuse across rows)."""
    nq = -(-Sq // bq)
    q = B * H * Sq * hd
    kv = 2 * B * H * nq * Sk * hd
    o = B * H * Sq * hd
    return (q + kv + o) * itemsize


def fum_bytes(B, H, Sq, Sk, hd, bq, bk, counts, itemsize=4) -> int:
    """FUM: K/V fetched only for kept blocks (counts [B,H,nq])."""
    q = B * H * Sq * hd
    kept = int(np.asarray(counts).sum())
    kv = 2 * kept * bk * hd
    o = B * H * Sq * hd
    return (q + kv + o) * itemsize


def run() -> List[Dict]:
    rows = []
    for (B, H, S, hd) in SHAPES:
        rng = jax.random.PRNGKey(42)
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (B, H, S, hd), jnp.float32) * 1.3
        k = jax.random.normal(kk, (B, H, S, hd), jnp.float32) * 1.3
        v = jax.random.normal(kv, (B, H, S, hd), jnp.float32)

        # dense flash kernel vs oracle
        bq = bk = min(128, S)
        out_f = ops.flash(q, k, v, causal=True, block_q=bq, block_k=bk)
        ref_f = kref.flash_attention_ref(q, k, v, causal=True)
        err_f = float(jnp.abs(out_f - ref_f).max())

        # HDP pipeline kernel vs batched-core reference
        hdp = HDPConfig(rho_b=0.5, block_q=bq, block_k=bk, causal=True,
                        head_pruning=False)
        out_h, st = ops.hdp_attention_tpu(q, k, v, hdp, return_stats=True)
        ref_h, _ = hdp_attention(q, k, v, hdp)
        err_h = float(jnp.abs(out_h - ref_h).max())

        nq = S // bq
        dense_b = flash_bytes(B, H, S, S, hd, bq, bk)
        kept_per_row = float(st["kept_blocks_per_row"])
        counts = np.full((B, H, nq), kept_per_row)
        fum_b = fum_bytes(B, H, S, S, hd, bq, bk, counts)
        rows.append({
            "shape": f"{B}x{H}x{S}x{hd}",
            "flash_max_err": f"{err_f:.2e}",
            "hdp_max_err": f"{err_h:.2e}",
            "block_sparsity": round(float(st["block_sparsity"]), 3),
            "dense_hbm_mb": round(dense_b / 1e6, 2),
            "fum_hbm_mb": round(fum_b / 1e6, 2),
            "hbm_saving": round(1 - fum_b / dense_b, 3),
        })
    return rows


def main(quick: bool = False) -> List[Dict]:
    rows = run()
    print("# kernels (interpret-mode correctness + FUM traffic)")
    hdr = list(rows[0].keys())
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    return rows


if __name__ == "__main__":
    main()
