"""Render the dry-run roofline table (EXPERIMENTS.md §Roofline source).

Reads dryrun_results.json (written by repro.launch.dryrun) and prints the
per-(arch x shape x mesh) three-term roofline with bottleneck + useful
ratio. No model execution here — pure reporting.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_results.json")


def load(path: str = DEFAULT) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(results: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for e in results:
        if e["mesh"] != mesh:
            continue
        if e["status"] == "skip":
            rows.append({"arch": e["arch"], "shape": e["shape"],
                         "status": "skip"})
            continue
        if e["status"] != "ok":
            rows.append({"arch": e["arch"], "shape": e["shape"],
                         "status": "FAIL"})
            continue
        r = e["roofline"]
        dom = r["bottleneck"]
        dom_t = {"compute": r["compute_t"], "memory": r["memory_t"],
                 "collective": r["collective_t"]}[dom]
        rows.append({
            "arch": e["arch"], "shape": e["shape"], "status": "ok",
            "compute_ms": round(r["compute_t"] * 1e3, 2),
            "memory_ms": round(r["memory_t"] * 1e3, 2),
            "collective_ms": round(r["collective_t"] * 1e3, 2),
            "bottleneck": dom,
            "roofline_frac": round(r["compute_t"] / max(dom_t, 1e-12), 3),
            "useful_ratio": (round(r["useful_ratio"], 3)
                             if r.get("useful_ratio") else ""),
            "peak_gb": round(e["memory"]["peak_bytes"] / 1e9, 2),
            "fits_hbm": e["fits_hbm"],
        })
    return rows


def main(quick: bool = False, path: str = DEFAULT) -> List[Dict]:
    results = load(path)
    out = []
    for mesh in ("16x16", "2x16x16"):
        rows = table(results, mesh)
        print(f"# roofline (dry-run, mesh {mesh})")
        hdr = ["arch", "shape", "status", "compute_ms", "memory_ms",
               "collective_ms", "bottleneck", "roofline_frac",
               "useful_ratio", "peak_gb", "fits_hbm"]
        print(",".join(hdr))
        for r in rows:
            print(",".join(str(r.get(h, "")) for h in hdr))
        out.extend({**r, "mesh": mesh} for r in rows)
    return out


if __name__ == "__main__":
    main()
