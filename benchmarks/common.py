"""Shared benchmark substrate: trained tiny models + Q/K/V capture.

No GLUE/BERT checkpoints exist offline, so the paper-fidelity benchmarks
(Figs. 7-10 analogs) run on small LMs **trained in-framework** on the
synthetic pipeline (planted bigrams/motifs -> concentrated attention,
the structure HDP exploits). Two scales mirror the paper's pair:

* ``tiny`` — 2 layers x 2 heads (BERT-Tiny's head count): head pruning
  must be near-impossible without accuracy loss (paper Fig. 8c/d).
* ``base`` — 6 layers x 8 heads (48 heads; BERT-Base direction): head
  pruning should find redundant heads (paper Fig. 8a/b).

Fidelity metrics substitute accuracy (documented in DESIGN.md §1):
 - top-1 next-token agreement HDP-vs-dense on held-out batches
   (the "accuracy" axis of every figure analog),
 - attention-output cosine similarity per layer,
 - mask IoU vs the Top-K oracle.

Trained params are cached in ``.bench_cache/`` so reruns are fast.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, make_source
from repro.models import layers as L
from repro.models import registry
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step

CACHE_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), ".bench_cache")

SEQ = 128
VOCAB = 512


def model_cfg(scale: str) -> ModelConfig:
    """In-framework stand-ins for the paper's BERT-Tiny / BERT-Base pair."""
    if scale == "tiny":
        n_layers, n_heads, d = 2, 2, 128
    elif scale == "base":
        n_layers, n_heads, d = 6, 8, 256
    else:
        raise ValueError(scale)
    return ModelConfig(
        name=f"bench-{scale}", family="dense", n_layers=n_layers,
        d_model=d, n_heads=n_heads, n_kv_heads=n_heads, d_ff=4 * d,
        vocab_size=VOCAB, head_dim=d // n_heads, act="gelu",
        pos_emb="rope", norm="layernorm", dtype="float32", remat=False,
        attn_chunk=SEQ, hdp=None)


def _cache_path(scale: str, steps: int) -> str:
    return os.path.join(CACHE_DIR, f"{scale}_s{steps}.npz")


def train_model(scale: str, steps: int = 400, batch: int = 16,
                verbose: bool = True) -> Tuple[ModelConfig, Dict]:
    """Train (or load cached) a small LM; returns (cfg, params)."""
    cfg = model_cfg(scale)
    path = _cache_path(scale, steps)
    params, specs = registry.init_params(cfg, jax.random.PRNGKey(7))
    flat, treedef = jax.tree_util.tree_flatten(params)
    if os.path.exists(path):
        with np.load(path) as z:
            flat = [jnp.asarray(z[f"p{i}"]) for i in range(len(flat))]
        return cfg, jax.tree_util.tree_unflatten(treedef, flat)

    os.makedirs(CACHE_DIR, exist_ok=True)
    dcfg = DataConfig(VOCAB, SEQ, batch, seed=3, kind="synthetic")
    src = make_source(dcfg)
    ocfg = opt.OptConfig(peak_lr=1e-3, warmup_steps=20, decay_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    state = opt.init_opt_state(params)
    t0 = time.time()
    first = last = None
    for s in range(steps):
        tokens = src.batch_at(s)
        params, state, m = step_fn(params, state, {"tokens": tokens})
        if s == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if verbose and s % 100 == 0:
            print(f"  [{scale}] step {s} loss {last:.3f}", flush=True)
    if verbose:
        print(f"  [{scale}] trained {steps} steps in {time.time()-t0:.0f}s "
              f"loss {first:.3f} -> {last:.3f}", flush=True)
    flat = jax.tree_util.tree_flatten(params)[0]
    np.savez(path, **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})
    return cfg, params


def eval_batches(n: int = 4, batch: int = 8, seed: int = 1234) -> List[np.ndarray]:
    """Held-out batches (different seed stream than training)."""
    dcfg = DataConfig(VOCAB, SEQ, batch, seed=seed, kind="synthetic")
    src = make_source(dcfg)
    return [src.batch_at(10_000 + i) for i in range(n)]


# ------------------------------------------- pluggable-attention forward
def forward_with_attention(cfg: ModelConfig, params, tokens, attn_fn,
                           capture: Optional[List] = None) -> jnp.ndarray:
    """Dense-family forward with attention = ``attn_fn(layer, q, k, v)``.

    q/k/v are [B,H,S,hd]; attn_fn returns the attention output in the same
    layout. The Python layer loop lets baselines thread cross-layer state
    (SpAtten-style cascaded head pruning). When ``capture`` is a list, the
    per-layer {"q","k","v"} dict is appended to it. Logits are asserted
    against registry.apply_train in tests.
    """
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.hd
    x = L.embed_tokens(params["embed"], tokens, cfg.d_model)
    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        h = L.apply_norm(cfg, lp["ln1"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        kk = jnp.einsum("bsd,dnk->bsnk", h, lp["attn"]["wk"])
        vv = jnp.einsum("bsd,dnk->bsnk", h, lp["attn"]["wv"])
        positions = jnp.arange(S)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        kk = L.apply_rope(kk, positions, cfg.rope_theta)
        qh = q.transpose(0, 2, 1, 3)        # [B,H,S,hd]
        kh = kk.transpose(0, 2, 1, 3)
        vh = vv.transpose(0, 2, 1, 3)
        if capture is not None:
            capture.append({"q": qh, "k": kh, "v": vh})
        o = attn_fn(li, qh, kh, vh)
        a = jnp.einsum("bshk,hkd->bsd",
                       o.transpose(0, 2, 1, 3), lp["attn"]["wo"])
        x = x + a
        h2 = L.apply_norm(cfg, lp["ln2"], x)
        x = x + L.mlp_apply(cfg, lp["ffn"], h2)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_logits(params["embed"], x)


def capture_qkv(cfg: ModelConfig, params, tokens) -> List[Dict[str, jnp.ndarray]]:
    """Per-layer Q/K/V [B,H,S,hd] under the exact dense forward."""
    from repro.core.hdp import dense_attention_reference
    cap: List[Dict[str, jnp.ndarray]] = []
    forward_with_attention(
        cfg, params, tokens,
        lambda li, q, k, v: dense_attention_reference(q, k, v, causal=True),
        capture=cap)
    return cap


def agreement_with(cfg, params, attn_fn, batches) -> float:
    """Top-1 agreement of a pluggable-attention forward vs exact dense."""
    from repro.core.hdp import dense_attention_reference
    dense = lambda li, q, k, v: dense_attention_reference(  # noqa: E731
        q, k, v, causal=True)
    agree = total = 0
    for b in batches:
        t = jnp.asarray(b)
        ad = jnp.argmax(forward_with_attention(cfg, params, t, dense), -1)
        av = jnp.argmax(forward_with_attention(cfg, params, t, attn_fn), -1)
        agree += int((ad == av).sum())
        total += t.size
    return agree / max(total, 1)


def forward_logits(cfg: ModelConfig, params, tokens,
                   hdp=None) -> jnp.ndarray:
    """Full forward; hdp=None -> dense, else HDP active in attention."""
    run_cfg = cfg if hdp is None else cfg.replace(
        hdp=hdp.replace(enabled=True, apply_in_training=True, causal=True))
    logits, _ = registry.apply_train(run_cfg, params, {"tokens": tokens})
    return logits


def top1_agreement(cfg, params, hdp, batches) -> float:
    """Fraction of positions where HDP and dense pick the same next token.

    This is the benchmark's accuracy proxy: on a classification task the
    accuracy drop is bounded by (1 - agreement)."""
    agree = total = 0
    f_dense = jax.jit(lambda t: jnp.argmax(
        forward_logits(cfg, params, t), -1))
    f_hdp = jax.jit(lambda t: jnp.argmax(
        forward_logits(cfg, params, t, hdp), -1))
    for b in batches:
        t = jnp.asarray(b)
        agree += int((f_dense(t) == f_hdp(t)).sum())
        total += t.size
    return agree / max(total, 1)


def hdp_sparsity(cfg, params, hdp, batches) -> Dict[str, float]:
    """Mean achieved sparsities over eval batches (uses model-level stats)."""
    run_cfg = cfg.replace(hdp=hdp.replace(
        enabled=True, apply_in_training=True, causal=True))

    @jax.jit
    def stats_of(t):
        _, extras = registry.apply_train(run_cfg, params, {"tokens": t},
                                         collect_stats=True)
        s = extras["hdp"]
        return (jnp.mean(s["block_sparsity"]), jnp.mean(s["head_sparsity"]))

    bs, hs = [], []
    for b in batches:
        x, y = stats_of(jnp.asarray(b))
        bs.append(float(x))
        hs.append(float(y))
    return {"block_sparsity": float(np.mean(bs)),
            "head_sparsity": float(np.mean(hs))}


def cosine(a: jnp.ndarray, b: jnp.ndarray) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if na == 0 or nb == 0:
        return 1.0 if na == nb else 0.0
    return float(a @ b / (na * nb))
