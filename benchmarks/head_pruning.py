"""Fig. 8 + Fig. 11 analogs — early head pruning and SpAtten comparison.

Fig. 8: sweep tau_H (as percentiles of the observed theta_head
distribution so the sweep is model-independent); report heads-pruned-%
and top-1 agreement, on both the tiny (2x2=4 heads) and base (6x8=48
heads) models. Expected paper behaviour: the tiny model cannot lose even
one head cheaply (one head = 25% of capacity); the base model prunes
10-20% of heads with little loss.

Fig. 11 (SpAtten comparison): HDP prunes per-layer (head importance is
data- AND layer-dependent, paper Fig. 2); SpAtten cascades — once pruned
at layer l, a head stays pruned for all later layers, with importance
accumulated from attention outputs. Both are run at matched head-pruning
percentages; per-layer should degrade more gracefully at high ratios.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.config import HDPConfig
from repro.core.hdp import dense_attention_reference, hdp_attention

PCTS = (0, 5, 10, 15, 25, 35, 50, 70)


def theta_head_samples(cfg, params, batches, hdp: HDPConfig) -> np.ndarray:
    """Observed theta_head across layers/batches/heads (for thresholds)."""
    vals = []
    for b in batches:
        caps = common.capture_qkv(cfg, params, jnp.asarray(b))
        for c in caps:
            _, st = hdp_attention(c["q"], c["k"], c["v"], hdp)
            vals.append(np.asarray(st.theta_head).ravel())
    return np.concatenate(vals)


def _hdp_attn_fn(hdp: HDPConfig):
    def fn(li, q, k, v):
        out, _ = hdp_attention(q, k, v, hdp)
        return out
    return fn


def _cascade_attn_fn(cfg, prune_frac: float):
    """SpAtten-style cascade (reimplemented): head importance accumulates
    across layers from |attention output|; the bottom `prune_frac * l/L`
    heads at layer l are pruned and stay pruned."""
    state = {"score": None, "pruned": None}
    L_ = cfg.n_layers

    def fn(li, q, k, v):
        out = dense_attention_reference(q, k, v, causal=True)
        imp = jnp.abs(out).sum(axis=(-2, -1))          # [B, H]
        if state["score"] is None or li == 0:
            state["score"] = imp
            state["pruned"] = jnp.zeros_like(imp, bool)
        else:
            state["score"] = state["score"] + imp
        # cascade budget: prune_frac of heads by the last layer, linearly
        n_prune = int(round(prune_frac * q.shape[1] * (li + 1) / L_))
        if n_prune > 0:
            score = jnp.where(state["pruned"], -jnp.inf, state["score"])
            order = jnp.argsort(score, axis=-1)         # ascending
            new_pruned = jnp.zeros_like(state["pruned"])
            rows = jnp.arange(score.shape[0])[:, None]
            already = state["pruned"].sum(-1, keepdims=True)
            take = jnp.maximum(n_prune - already, 0)
            idx = order[:, :n_prune]
            mask = jnp.arange(n_prune)[None, :] < take
            new_pruned = new_pruned.at[rows, idx].set(mask)
            state["pruned"] = state["pruned"] | new_pruned
        gate = 1.0 - state["pruned"].astype(out.dtype)
        return out * gate[:, :, None, None]
    return fn


def run(scale: str, n_eval: int = 2, train_steps: int = 400) -> List[Dict]:
    cfg, params = common.train_model(scale, steps=train_steps)
    batches = common.eval_batches(n_eval)
    base_hdp = HDPConfig(rho_b=0.3, head_pruning=True, tau_h=-1.0,
                         block_pruning=False, causal=True)
    th = theta_head_samples(cfg, params, batches[:1], base_hdp)
    rows = []
    for pct in PCTS:
        tau = float(np.percentile(th, pct)) if pct > 0 else -1.0
        hdp = base_hdp.replace(tau_h=tau)
        ag = common.agreement_with(cfg, params, _hdp_attn_fn(hdp), batches)
        sp = common.hdp_sparsity(
            cfg, params, hdp.replace(block_pruning=False), batches[:1])
        rows.append({"method": "hdp_per_layer", "pct": pct,
                     "tau_h": round(tau, 1),
                     "heads_pruned": round(sp["head_sparsity"], 4),
                     "agreement": round(ag, 4)})
    return rows


def run_cascade(scale: str = "base", n_eval: int = 2,
                train_steps: int = 400) -> List[Dict]:
    cfg, params = common.train_model(scale, steps=train_steps)
    batches = common.eval_batches(n_eval)
    rows = []
    for frac in (0.0, 0.1, 0.17, 0.25, 0.35, 0.5):
        ag = common.agreement_with(cfg, params,
                                   _cascade_attn_fn(cfg, frac), batches)
        rows.append({"method": "spatten_cascade", "head_frac": frac,
                     "agreement": round(ag, 4)})
    # per-layer HDP at matched fractions (via tau percentile = frac)
    base_hdp = HDPConfig(rho_b=0.3, head_pruning=True, tau_h=-1.0,
                         block_pruning=False, causal=True)
    th = theta_head_samples(cfg, params, batches[:1], base_hdp)
    for frac in (0.0, 0.1, 0.17, 0.25, 0.35, 0.5):
        tau = float(np.percentile(th, 100 * frac)) if frac else -1.0
        hdp = base_hdp.replace(tau_h=tau)
        ag = common.agreement_with(cfg, params, _hdp_attn_fn(hdp), batches)
        rows.append({"method": "hdp_per_layer", "head_frac": frac,
                     "agreement": round(ag, 4)})
    return rows


def main(quick: bool = False) -> List[Dict]:
    out = []
    for scale in ("tiny", "base"):
        rows = run(scale, n_eval=1 if quick else 2,
                   train_steps=200 if quick else 400)
        print(f"# head_pruning (Fig.8 analog) scale={scale}")
        print("method,pct,tau_h,heads_pruned,agreement")
        for r in rows:
            print(f"{r['method']},{r['pct']},{r['tau_h']},"
                  f"{r['heads_pruned']},{r['agreement']}")
        out.extend({**r, "scale": scale} for r in rows)
    rows = run_cascade("base", n_eval=1 if quick else 2,
                       train_steps=200 if quick else 400)
    print("# head_pruning cascade (Fig.11 analog, SpAtten-style) scale=base")
    print("method,head_frac,agreement")
    for r in rows:
        print(f"{r['method']},{r['head_frac']},{r['agreement']}")
    out.extend({**r, "scale": "base"} for r in rows)
    return out


if __name__ == "__main__":
    main()
