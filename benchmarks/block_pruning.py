"""Fig. 7 analog — HDP block pruning vs the Top-K oracle.

Sweeps rho_B (both branches of Alg. 2 line 15) and the Top-K keep ratio;
reports, per point:

  method, param, achieved block sparsity, top-1 agreement vs dense
  (accuracy proxy), mean attention-output cosine, mask IoU vs Top-K at
  matched sparsity.

Expected paper behaviour to check: HDP tracks Top-K closely up to ~70%
sparsity and diverges past ~80% (the mean!=median assumption breaks —
the achieved sparsity stops following the requested rho_B).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import topk
from repro.core.config import HDPConfig
from repro.core.hdp import hdp_attention

RHO_GRID = (-0.8, -0.5, -0.2, 0.01, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9)
KEEP_GRID = (0.9, 0.75, 0.6, 0.45, 0.3, 0.2, 0.1, 0.05)


def _hdp_cfg(rho: float, block: int) -> HDPConfig:
    return HDPConfig(rho_b=rho, block_q=block, block_k=block,
                     head_pruning=False, approx=True, causal=True)


def _hdp_attn_fn(hdp: HDPConfig):
    def fn(li, q, k, v):
        out, _ = hdp_attention(q, k, v, hdp)
        return out
    return fn


def _topk_attn_fn(keep_ratio: float, block: int):
    def fn(li, q, k, v):
        out, _ = topk.topk_attention(q, k, v, block, block, keep_ratio,
                                     causal=True)
        return out
    return fn


def run(scale: str = "base", block: int = 2, n_eval: int = 2,
        train_steps: int = 400) -> List[Dict]:
    cfg, params = common.train_model(scale, steps=train_steps)
    batches = common.eval_batches(n_eval)
    caps = common.capture_qkv(cfg, params, jnp.asarray(batches[0]))
    rows = []

    # ---- Top-K oracle sweep (exact scores, per-row top-k blocks) ----
    topk_masks = {}
    for keep in KEEP_GRID:
        ag = common.agreement_with(cfg, params,
                                   _topk_attn_fn(keep, block), batches)
        sps, masks = [], []
        for c in caps:
            scores = jnp.einsum("bhqd,bhkd->bhqk", c["q"], c["k"])
            from repro.core import blocking
            valid = blocking.causal_block_valid(
                scores.shape[-2], scores.shape[-1], block, block)
            m = topk.topk_block_mask(scores, block, block, keep, valid)
            masks.append(m)
            nv = jnp.maximum(valid.sum() * np.prod(m.shape[:-2]), 1)
            sps.append(1.0 - float((m & valid).sum()) / float(nv))
        sp = float(np.mean(sps))
        topk_masks[keep] = masks
        rows.append({"method": "topk", "param": keep,
                     "block_sparsity": round(sp, 4),
                     "agreement": round(ag, 4)})

    # ---- HDP rho_B sweep ----
    for rho in RHO_GRID:
        hdp = _hdp_cfg(rho, block)
        ag = common.agreement_with(cfg, params, _hdp_attn_fn(hdp), batches)
        sps, cosines, masks = [], [], []
        for c in caps:
            out, st = hdp_attention(c["q"], c["k"], c["v"], hdp)
            from repro.core.hdp import dense_attention_reference
            ref = dense_attention_reference(c["q"], c["k"], c["v"],
                                            causal=True)
            cosines.append(common.cosine(out, ref))
            sps.append(float(st.block_sparsity))
            masks.append(st.keep_blocks)
        sp = float(np.mean(sps))
        # mask IoU vs the Top-K mask with the closest matched sparsity
        best_keep, best_d = None, 9e9
        for keep in KEEP_GRID:
            tk_sp = next(r["block_sparsity"] for r in rows
                         if r["method"] == "topk" and r["param"] == keep)
            if abs(tk_sp - sp) < best_d:
                best_keep, best_d = keep, abs(tk_sp - sp)
        ious = [float(topk.mask_agreement(m, tm))
                for m, tm in zip(masks, topk_masks[best_keep])]
        rows.append({"method": "hdp", "param": rho,
                     "block_sparsity": round(sp, 4),
                     "agreement": round(ag, 4),
                     "attn_cosine": round(float(np.mean(cosines)), 4),
                     "mask_iou_vs_topk": round(float(np.mean(ious)), 4),
                     "matched_topk_keep": best_keep})
    return rows


def main(scale: str = "base", quick: bool = False) -> List[Dict]:
    rows = run(scale, n_eval=1 if quick else 2,
               train_steps=200 if quick else 400)
    print(f"# block_pruning (Fig.7 analog) scale={scale}")
    hdr = ["method", "param", "block_sparsity", "agreement",
           "attn_cosine", "mask_iou_vs_topk"]
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


if __name__ == "__main__":
    main()
