"""Decode-step roofline: dense vs HDP-FUM on the dominant (memory) term.

Decode at 32k context is memory-bound everywhere (see §Roofline): the
step streams the weights once plus the KV cache. The paper's mechanism —
integer scout -> block mask -> Fetch-Upon-Mask — prunes KV *reads*:

    dense bytes = weights/shard + (K + V)
    HDP bytes   = weights/shard + int8-scout K + (1 - sparsity)(K + V)

The XLA-lowered dry-run cannot show this saving (XLA gathers all pages;
only the Pallas kernel's scalar-prefetched BlockSpecs skip the DMAs), so
this table combines the *measured* dry-run memory_t with the kernel's
deterministic DMA accounting at the *measured* serving sparsity. On TPU
the BlockSpec index_map decides traffic exactly, so the adjusted column
is arithmetic, not simulation.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.configs import SHAPES, get_config
from repro.models import registry
from repro.roofline.analysis import HBM_BW
from repro.serving.kv_cache import kv_read_bytes_per_step

DRYRUN = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_results.json")

ARCHS = ("chameleon-34b", "granite-8b", "llama4-scout-17b-a16e",
         "nemotron-4-15b")
MODEL_SHARDS = 16


def row(arch: str, sparsity: float, dryrun: List[Dict]) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES["decode_32k"]
    B_local = shape.global_batch // 16          # data-sharded batch
    weights = 2 * registry.param_count(cfg, active_only=True) / MODEL_SHARDS
    kv_dense, kv_hdp = kv_read_bytes_per_step(
        cfg, shape.seq_len, B_local, sparsity)
    # the KV cache itself is additionally sharded over `model`
    # (kv_heads or kv_seq), so per-device traffic divides by 16
    kv_dense /= MODEL_SHARDS
    kv_hdp /= MODEL_SHARDS
    dense_t = (weights + kv_dense) / HBM_BW
    hdp_t = (weights + kv_hdp) / HBM_BW
    meas = next((e["roofline"]["memory_t"] for e in dryrun
                 if e["arch"] == arch and e["shape"] == "decode_32k"
                 and e["mesh"] == "16x16" and e["status"] == "ok"), None)
    return {
        "arch": arch,
        "measured_xla_ms": round(meas * 1e3, 1) if meas else "",
        "analytic_dense_ms": round(dense_t * 1e3, 2),
        "analytic_hdp_ms": round(hdp_t * 1e3, 2),
        "hdp_speedup": round(dense_t / hdp_t, 2),
        "kv_frac_of_dense": round(kv_dense / (weights + kv_dense), 3),
        "sparsity": sparsity,
    }


def main(quick: bool = False, sparsity: float = 0.68) -> List[Dict]:
    """sparsity default = measured serving block sparsity (serve_hdp)."""
    dryrun = json.load(open(DRYRUN)) if os.path.exists(DRYRUN) else []
    rows = [row(a, sparsity, dryrun) for a in ARCHS]
    print("# decode_roofline (32k decode, per device; HDP-FUM at measured "
          f"block sparsity {sparsity})")
    hdr = list(rows[0].keys())
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    return rows


if __name__ == "__main__":
    main()
