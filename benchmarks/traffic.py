"""Seeded synthetic serving traffic: arrivals + length distributions.

Produces the request stream the `serving_stream` benchmark (and any
ad-hoc load experiment) feeds the engine: per request an arrival time in
*engine steps* (the serve loop's discrete clock, so traces replay
identically regardless of host speed), a prompt of sampled length, and a
sampled output budget. Everything is drawn from one `numpy` Generator
seeded by ``TrafficConfig.seed`` — the same config always yields the
same trace, byte for byte (pinned in tests/test_scheduler.py).

Arrival processes:

* ``poisson`` — exponential inter-arrival gaps with mean ``1/rate``
  steps: the steady mixed-load case continuous batching exists for.
* ``burst`` — everything arrives at step 0: the closed-batch worst case
  (maximal queue depth, admission purely budget/ordering driven).

Length distributions are uniform-integer ranges; mixed short/long loads
come from ``long_frac``: that fraction of requests (the trace's tail,
interleaved deterministically by the rng) instead draws from the
``long_lo..long_hi`` prompt range — the chunked-prefill-under-decode
workload.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TrafficConfig:
    n_requests: int = 16
    arrival: str = "poisson"          # "poisson" | "burst"
    rate: float = 0.5                 # mean arrivals per engine step
    prompt_lo: int = 4                # uniform prompt-length range
    prompt_hi: int = 24
    max_new_lo: int = 4               # uniform output-budget range
    max_new_hi: int = 8
    long_frac: float = 0.0            # fraction drawing the long range
    long_lo: int = 48
    long_hi: int = 80
    vocab: int = 250
    seed: int = 0

    def __post_init__(self):
        if self.arrival not in ("poisson", "burst"):
            raise ValueError(f"arrival must be 'poisson' or 'burst', "
                             f"got {self.arrival!r}")
        if self.arrival == "poisson" and self.rate <= 0:
            raise ValueError(f"poisson arrivals need rate > 0, "
                             f"got {self.rate}")
        if not 0.0 <= self.long_frac <= 1.0:
            raise ValueError(f"long_frac must be in [0, 1], "
                             f"got {self.long_frac}")


@dataclasses.dataclass
class SyntheticRequest:
    uid: int
    arrival_step: int
    prompt: List[int]
    max_new_tokens: int


def generate(cfg: TrafficConfig,
             vocab: Optional[int] = None) -> List[SyntheticRequest]:
    """The deterministic trace for ``cfg``: requests sorted by arrival
    step (uid order = arrival order; ties keep uid order)."""
    rng = np.random.default_rng(cfg.seed)
    vocab = vocab if vocab is not None else cfg.vocab
    n = cfg.n_requests
    if cfg.arrival == "burst":
        arrive = np.zeros(n, dtype=int)
    else:
        gaps = rng.exponential(1.0 / cfg.rate, size=n)
        arrive = np.floor(np.cumsum(gaps)).astype(int)
    is_long = rng.random(n) < cfg.long_frac
    out = []
    for uid in range(n):
        lo, hi = ((cfg.long_lo, cfg.long_hi) if is_long[uid]
                  else (cfg.prompt_lo, cfg.prompt_hi))
        plen = int(rng.integers(lo, max(hi, lo + 1)))
        prompt = rng.integers(1, vocab, size=plen).tolist()
        max_new = int(rng.integers(cfg.max_new_lo,
                                   max(cfg.max_new_hi, cfg.max_new_lo + 1)))
        out.append(SyntheticRequest(uid, int(arrive[uid]), prompt, max_new))
    return out


def replay(engine, trace: List[SyntheticRequest], request_cls,
           max_steps: int = 100_000) -> Tuple[dict, int]:
    """Drive ``engine`` through ``trace`` on the discrete step clock:
    each request is submitted once the engine has run ``arrival_step``
    steps, so mid-run admission is exercised deterministically. Returns
    (results, steps run)."""
    pending = list(trace)
    step = 0
    while pending or engine._n_pending():
        while pending and pending[0].arrival_step <= step:
            r = pending.pop(0)
            engine.submit(request_cls(r.uid, r.prompt,
                                      max_new_tokens=r.max_new_tokens))
        engine.step()
        step += 1
        if step > max_steps:
            raise RuntimeError(f"traffic replay exceeded {max_steps} steps")
    return engine.results(), step
