"""Distributed-optimization helpers.

Gradient compression: a custom_vjp identity whose backward casts cotangents
to bf16. Placed at parameter use-sites, it makes autodiff *produce* bf16
gradients, so the cross-`data`/`pod` all-reduce XLA inserts moves half the
bytes. The optimizer upcasts back to fp32 before the update (error is
bounded by bf16 rounding of the *summed* gradient — standard practice at
pod scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def compress_grads_bf16(x):
    return x


def _fwd(x):
    return x, None


def _bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype)
            if g.dtype == jnp.float32 else g,)


compress_grads_bf16.defvjp(_fwd, _bwd)


def maybe_compress(params, mode: str):
    """Apply gradient compression to every leaf ('bf16') or pass through."""
    if mode == "none":
        return params
    if mode == "bf16":
        return jax.tree.map(compress_grads_bf16, params)
    raise ValueError(f"unknown gradient compression mode {mode!r}")
