"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Params and activations are annotated with *logical* axis names; a rule set
maps logical names to physical mesh axes. Models call
:func:`shard_activation` at layer boundaries; outside a
:func:`logical_axis_rules` context (unit tests, single device) it is a
no-op, so models stay mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# -------------------------------------------------------------------- rules
# Tensor-parallel default: weights sharded on `model` only; optimizer states
# additionally ZeRO-1 sharded over `data` (see training/train_loop.py).
RULES_TP: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "table_embed": None,   # vocab-table d_model dim: never FSDP over data
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "layers": None,
    "groups": None,
    "state": None,
    "conv": None,
    "kv_seq": None,
    # activation-only axes
    "heads_act": "model",
    "mlp_act": "model",
    "embed_act": None,
    "seq_act": None,
    "vocab_act": "model",
    "experts_act": "model",
}

# FSDP+TP: large weight matrices additionally sharded over `data` on their
# embed/replicated dimension (ZeRO-3-like; XLA all-gathers on use).
RULES_FSDP_TP = dict(RULES_TP, embed=("pod", "data"))


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Axis]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: Dict[str, Axis]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _physical(axis: Axis, mesh: Mesh, rules: Dict[str, Axis]):
    if axis is None:
        return None
    name = rules.get(axis, None) if isinstance(axis, str) else axis
    if name is None:
        return None
    if isinstance(name, str):
        return name if name in mesh.axis_names else None
    present = tuple(a for a in name if a in mesh.axis_names)
    return present if present else None


def spec_for(logical: Sequence[Axis], shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, Axis]] = None) -> P:
    """Resolve logical axes -> PartitionSpec.

    Drops non-divisible shards, and deduplicates mesh axes across dims
    (a mesh axis may shard at most one dim; first occurrence wins — e.g.
    MoE ``(experts, mlp, embed)`` with both experts and mlp -> ``model``
    resolves to pure expert parallelism).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None or rules is None:
        return P()
    out = []
    used: set = set()
    for dim, ax in zip(shape, logical):
        phys = _physical(ax, mesh, rules)
        if phys is None:
            out.append(None)
            continue
        names = (phys,) if isinstance(phys, str) else tuple(phys)
        names = tuple(a for a in names if a not in used)
        if not names:
            out.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if dim % size == 0:
            used.update(names)
            out.append(names[0] if len(names) == 1 else names)
        else:
            out.append(None)
    return P(*out)


def tree_specs(params, logical_tree, mesh: Mesh, rules: Dict[str, Axis]):
    """Map a (params, logical-axes) tree pair to NamedShardings."""
    def one(p, ax):
        return NamedSharding(mesh, spec_for(ax, p.shape, mesh, rules))
    return jax.tree.map(one, params, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, (str, tuple)) for a in x))


def shard_activation(x, *logical: Axis):
    """with_sharding_constraint by logical axes; no-op outside a context."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    if len(logical) < x.ndim:
        logical = tuple(logical) + (None,) * (x.ndim - len(logical))
    spec = spec_for(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def zero1_spec(logical: Sequence[Axis], shape: Sequence[int],
               mesh: Mesh, rules: Dict[str, Axis]) -> P:
    """Optimizer-state spec: like the weight but with `data` added on the
    largest still-unsharded divisible dim (ZeRO-1)."""
    base = spec_for(logical, shape, mesh, rules)
    parts = list(base) + [None] * (len(shape) - len(base))
    if any(p is not None and "data" in (p if isinstance(p, tuple) else (p,))
           for p in parts):
        return base
    dsz = mesh.shape.get("data", 1)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if parts[i] is None and shape[i] % dsz == 0 and shape[i] >= dsz:
            parts[i] = "data"
            return P(*parts)
        if parts[i] is not None:
            phys = parts[i] if isinstance(parts[i], tuple) else (parts[i],)
            if "data" not in phys and "model" in phys:
                sz = dsz * mesh.shape["model"]
                if shape[i] % sz == 0:
                    parts[i] = tuple(phys) + ("data",)
                    return P(*parts)
    return base
