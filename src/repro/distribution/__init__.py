from repro.distribution.sharding import (
    RULES_FSDP_TP,
    RULES_TP,
    logical_axis_rules,
    shard_activation,
    spec_for,
    tree_specs,
    zero1_spec,
)

__all__ = [
    "RULES_TP", "RULES_FSDP_TP", "logical_axis_rules", "shard_activation",
    "spec_for", "tree_specs", "zero1_spec",
]
