from repro.distribution.sharding import (
    RULES_FSDP_TP,
    RULES_TP,
    logical_axis_rules,
    shard_activation,
    spec_for,
    tree_specs,
    zero1_spec,
)
from repro.distribution.tp import (
    active_serving_mesh,
    active_tp,
    pool_pspec,
    pool_shardings,
    serving_mesh,
    tp_paged_attention,
)

__all__ = [
    "RULES_TP", "RULES_FSDP_TP", "logical_axis_rules", "shard_activation",
    "spec_for", "tree_specs", "zero1_spec",
    "active_serving_mesh", "active_tp", "pool_pspec", "pool_shardings",
    "serving_mesh", "tp_paged_attention",
]
