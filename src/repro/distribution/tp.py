"""Tensor-parallel serving: head-sharded paged HDP attention.

HDP prunes per head — the scout's block keep mask and the early head
gate (`theta_head > tau_h`, an absolute threshold with no cross-head
reduction, see ``core.hdp.decode_scout``) are computed independently
per KV head. That makes the head axis the natural shard dimension for
serving: under a ``(data, model)`` mesh each "model" shard holds 1/TP
of the paged pool (int8 codes + scales + scout views) and runs the
scout, the keep mask, and stage 3 purely on its local heads. The
pruned-pages-never-DMA contract holds per shard: a shard's fetched set
is the OR of *its* heads' keep masks, a subset of the global fetched
set, and masked softmax zeroes non-kept pages exactly — so per-head
outputs are bitwise identical at any TP degree.

The only cross-shard traffic is one all-gather of the per-head
attention output before the output projection (an exact concatenation,
no float reduction — byte identity is preserved; the ISSUE's
psum-the-projection variant would introduce a TP-dependent summation
order). Sparsity stats are shard-local DMA accounting and are pmean'd
over the model axis; ``theta_head`` is all-gathered back to full width.

The mesh is threaded as ambient context (thread-local, like
``distribution.sharding``): the engine wraps its jit'd steps in
:func:`serving_mesh`, and the model layer consults
:func:`active_serving_mesh` at trace time to route paged-decode calls
through :func:`tp_paged_attention`.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from functools import partial
from typing import Optional

import jax

_ctx = threading.local()

#: head (sharded) axis index of each pool leaf in the FULL pool
#: [L, P, ps, N, hd] / scales [L, P, N]; per-layer views drop the
#: leading L. Scout views mirror the page layout.
POOL_HEAD_AXIS = {
    "k_pages": 3, "v_pages": 3, "k_scout": 3, "f_scout": 3,
    "k_scale": 2, "v_scale": 2,
}


@contextmanager
def serving_mesh(mesh: Optional[jax.sharding.Mesh]):
    """Make ``mesh`` the ambient serving mesh for the calling thread."""
    prev = getattr(_ctx, "mesh", None)
    _ctx.mesh = mesh
    try:
        yield mesh
    finally:
        _ctx.mesh = prev


def active_serving_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_ctx, "mesh", None)


def active_tp() -> int:
    """TP degree of the ambient serving mesh (1 when unsharded)."""
    mesh = active_serving_mesh()
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("model", 1))


def _pspec(*axes):
    return jax.sharding.PartitionSpec(*axes)


def pool_pspec(name: str, *, per_layer: bool = False):
    """PartitionSpec sharding pool leaf ``name`` on the model axis."""
    ax = POOL_HEAD_AXIS.get(name)
    if ax is None:
        return _pspec()
    if per_layer:
        ax -= 1
    return _pspec(*([None] * ax + ["model"]))


def pool_shardings(mesh: jax.sharding.Mesh, pool: dict, *,
                   per_layer: bool = False) -> dict:
    """NamedSharding per pool leaf: heads on "model", rest replicated."""
    return {name: jax.sharding.NamedSharding(
        mesh, pool_pspec(name, per_layer=per_layer)) for name in pool}


def constrain_pool(pool: dict, mesh: Optional[jax.sharding.Mesh], *,
                   per_layer: bool = False) -> dict:
    """Re-assert pool shardings inside a jit body (no-op without mesh)."""
    if mesh is None:
        return pool
    sh = pool_shardings(mesh, pool, per_layer=per_layer)
    return {name: jax.lax.with_sharding_constraint(leaf, sh[name])
            for name, leaf in pool.items()}


def replicated(x, mesh: Optional[jax.sharding.Mesh]):
    """Constrain ``x`` (pytree) to fully-replicated on ``mesh``."""
    if mesh is None:
        return x
    sh = jax.sharding.NamedSharding(mesh, _pspec())
    return jax.tree.map(
        lambda leaf: jax.lax.with_sharding_constraint(leaf, sh), x)


def tp_paged_attention(q, call, spec, *, q_pos, k_pos, cache, page_table,
                       mesh: jax.sharding.Mesh):
    """Head-sharded paged-decode attention under ``mesh``.

    ``q`` [B,N,G,Sq,hd] with N the KV-head axis; ``cache`` is the
    per-layer pool view (pages [P,ps,N,hd], scales [P,N]). Each model
    shard runs the registry dispatch on its local head slice — the
    scout, keep mask, page gather, and stage-3 kernel all see
    N/tp heads and a per-shard fetched set. Returns the full-width
    ``(out, stats)`` with ``out`` constrained replicated (exact
    all-gather concat over heads, no float reduction).
    """
    from jax.experimental.shard_map import shard_map

    from repro.attention.registry import attention
    from repro.attention.stats import AttnStats

    tp = int(dict(mesh.shape).get("model", 1))
    n_kv = q.shape[1]
    if tp == 1 or n_kv % tp != 0:
        return attention(q, None, None, call, spec=spec, q_pos=q_pos,
                         k_pos=k_pos, cache=cache, page_table=page_table)

    q_spec = _pspec(None, "model")
    cache_specs = {name: pool_pspec(name, per_layer=True) for name in cache}

    def body(q_l, cache_l, table, qp, kp):
        out, stats = attention(q_l, None, None, call, spec=spec, q_pos=qp,
                               k_pos=kp, cache=cache_l, page_table=table)
        if stats is not None:
            gather = jax.lax.all_gather
            stats = AttnStats(
                block_sparsity=jax.lax.pmean(stats.block_sparsity, "model"),
                head_sparsity=jax.lax.pmean(stats.head_sparsity, "model"),
                theta_head=(None if stats.theta_head is None else
                            gather(stats.theta_head, "model", axis=1,
                                   tiled=True)),
                page_sparsity=(None if stats.page_sparsity is None else
                               jax.lax.pmean(stats.page_sparsity, "model")))
        return out, stats

    # stats presence/fields are call-static — derive the output pytree
    # structure from an unsharded abstract trace (the body itself uses
    # collectives, which only trace inside shard_map) so out_specs
    # matches exactly (None fields stay None)
    out_shape = jax.eval_shape(
        lambda q_, c_, t_, qp_, kp_: attention(
            q_, None, None, call, spec=spec, q_pos=qp_, k_pos=kp_,
            cache=c_, page_table=t_),
        q, cache, page_table, q_pos, k_pos)
    out_specs = (q_spec, jax.tree.map(lambda _: _pspec(), out_shape[1]))

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, cache_specs, _pspec(), _pspec(), _pspec()),
        out_specs=out_specs, check_rep=False)
    out, stats = sharded(q, cache, page_table, q_pos, k_pos)
    # exact all-gather of the head-sharded output before the o-projection:
    # every shard then computes the (replicated) wo einsum on full width
    out = replicated(out, mesh)
    return out, stats
