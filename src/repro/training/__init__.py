from repro.training import optimizer, train_loop

__all__ = ["optimizer", "train_loop"]
