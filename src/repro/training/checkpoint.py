"""Fault-tolerant checkpointing: atomic, sharded, elastically reshardable.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json          # tree structure, shapes/dtypes, step, meta
        shard_00000.npz        # this host's leaves (full logical arrays)
    <dir>/LATEST               # atomically-updated pointer file

Guarantees:

* **Atomic**: writes go to ``step_X.tmp_<nonce>`` and are renamed into
  place only after everything (including the manifest) is fsync'd; a crash
  mid-save never corrupts the previous checkpoint, and ``LATEST`` is
  updated last via rename (POSIX-atomic).
* **Elastic**: leaves are stored as *full logical arrays* (gathered via
  ``jax.device_get``), so a checkpoint written on a (16,16) mesh restores
  onto (2,16,16), (8,), or a single CPU device — ``load_checkpoint`` takes
  target shardings and ``jax.device_put``s each leaf. Mesh shape is
  metadata, not a constraint.
* **Self-describing**: the manifest records the flattened tree structure
  (jax.tree_util serialization) + per-leaf shape/dtype, validated on load.
* **Retention**: ``keep`` most recent checkpoints are retained; older ones
  are deleted only after a newer save fully commits.

Multi-host note: on a real cluster each host would write only its
addressable shards (process-sliced); here ``jax.process_count() == 1`` so
host 0 writes everything. The manifest format already carries
``process_count`` so the loader can detect and refuse mixed layouts.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_LATEST = "LATEST"


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


def _tree_paths(tree) -> List[str]:
    """Stable '/'-joined key path per leaf (for the manifest)."""
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_checkpoint(directory: str, step: int, state: Dict[str, Any], *,
                    keep: int = 3, meta: Optional[Dict] = None) -> str:
    """Atomically persist ``state`` (arbitrary pytree of arrays + scalars).

    Returns the committed checkpoint path.
    """
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp_", dir=directory)
    try:
        arrays = {_leaf_key(i): a for i, a in enumerate(host_leaves)}
        shard_path = os.path.join(tmp, "shard_00000.npz")
        np.savez(shard_path, **arrays)

        manifest = {
            "format": "repro-ckpt-v1",
            "step": int(step),
            "time": time.time(),
            "process_count": jax.process_count(),
            "n_leaves": len(host_leaves),
            "treedef": str(treedef),
            "paths": _tree_paths(state),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in host_leaves],
            "meta": meta or {},
        }
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())

        if os.path.exists(final):          # overwrite-same-step: replace
            shutil.rmtree(final)
        os.rename(tmp, final)              # commit point
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    # LATEST pointer: write-then-rename (atomic on POSIX).
    lp = os.path.join(directory, _LATEST)
    with tempfile.NamedTemporaryFile("w", dir=directory, delete=False) as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
        tmp_latest = f.name
    os.rename(tmp_latest, lp)

    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and ".tmp_" not in d)
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # Garbage-collect orphaned tmp dirs from crashed saves.
    for d in os.listdir(directory):
        if ".tmp_" in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    lp = os.path.join(directory, _LATEST)
    if not os.path.exists(lp):
        return None
    name = open(lp).read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(os.path.join(path, _MANIFEST)):
        # LATEST points at a deleted/corrupt dir; fall back to newest valid.
        cands = sorted(
            d for d in os.listdir(directory)
            if d.startswith("step_") and ".tmp_" not in d
            and os.path.exists(os.path.join(directory, d, _MANIFEST)))
        if not cands:
            return None
        name = cands[-1]
    return int(name.split("_")[1])


def load_checkpoint(directory: str, like: Dict[str, Any], *,
                    step: Optional[int] = None,
                    shardings: Optional[Any] = None,
                    ) -> Tuple[Dict[str, Any], int, Dict]:
    """Restore a checkpoint into the structure of ``like``.

    ``like`` supplies the target treedef (values may be abstract —
    ShapeDtypeStructs are fine). ``shardings``: optional matching pytree of
    (Named)Shardings — this is the **elastic reshard** path: leaves stored
    as full logical arrays are device_put onto whatever mesh the caller is
    running now. Returns (state, step, meta).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target tree has "
            f"{len(like_leaves)} — structure mismatch (paths in manifest: "
            f"{manifest['paths'][:5]}...)")

    with np.load(os.path.join(path, "shard_00000.npz")) as z:
        raw = [z[_leaf_key(i)] for i in range(manifest["n_leaves"])]

    for i, (a, spec, tgt) in enumerate(
            zip(raw, manifest["leaves"], like_leaves)):
        if list(a.shape) != list(getattr(tgt, "shape", a.shape)):
            raise ValueError(
                f"leaf {manifest['paths'][i]}: checkpoint shape {a.shape} "
                f"!= target {tgt.shape} (elastic reshard changes layout, "
                "not logical shapes)")

    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(raw))
    out = []
    for a, tgt, sh in zip(raw, like_leaves, sh_leaves):
        dt = getattr(tgt, "dtype", a.dtype)
        arr = a.astype(dt) if str(dt) != str(a.dtype) else a
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, step, manifest.get("meta", {})


class CheckpointManager:
    """Policy wrapper: save every N steps + on demand, resume, retention."""

    def __init__(self, directory: str, *, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self._last_saved = -1

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0 \
            and step != self._last_saved

    def save(self, step: int, state, meta=None) -> str:
        p = save_checkpoint(self.directory, step, state,
                            keep=self.keep, meta=meta)
        self._last_saved = step
        return p

    def maybe_save(self, step: int, state, meta=None) -> Optional[str]:
        if self.should_save(step):
            return self.save(step, state, meta)
        return None

    def restore_or(self, like, init_fn: Callable[[], Any], *,
                   shardings=None) -> Tuple[Any, int, Dict]:
        """Resume from latest if present, else ``init_fn()`` at step 0."""
        if latest_step(self.directory) is None:
            return init_fn(), 0, {}
        return load_checkpoint(self.directory, like, shardings=shardings)
