"""AdamW with fp32 master weights, global-norm clipping and a
warmup+cosine schedule — pure JAX (no optax available offline).

Model params stay in the compute dtype (bf16 on TPU -> gradient
all-reduces move half the bytes); the optimizer keeps fp32 master/m/v,
ZeRO-1 sharded over `data` by the launcher's out_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # scan the Adam update over the leading (layer-stack) dim of leaves
    # with >= this many elements: update temporaries shrink by the stack
    # length (0.5 GB -> 10 MB per expert matrix on llama4-scout). 0 = off.
    scan_update_min_elems: int = 0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(F32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        # copy=True: when params are already fp32, astype would alias the
        # param buffers and the train step's donation would see the same
        # buffer twice
        "master": jax.tree.map(lambda p: jnp.array(p, F32, copy=True),
                               params),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: OptConfig, grads, opt_state, param_dtype
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(g, m, v, w):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * w)
        return m, v, w

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_w = jax.tree.leaves(opt_state["master"])
    treedef = jax.tree.structure(grads)
    new_m, new_v, new_w = [], [], []
    thresh = cfg.scan_update_min_elems
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        if thresh and g.ndim >= 2 and g.size >= thresh:
            # layer-stacked leaf: scan the update over the leading dim so
            # only one slice of Adam temporaries is live at a time
            m2, v2, w2 = jax.lax.map(
                lambda args: upd(*args), (g, m, v, w))
        else:
            m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    master = jax.tree.unflatten(treedef, new_w)
    new_params = jax.tree.map(lambda w: w.astype(param_dtype), master)
    new_state = {"step": step,
                 "m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "master": master}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
