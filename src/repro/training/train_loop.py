"""Train step: loss, microbatch gradient accumulation, optimizer update.

The loss keeps the vocab dimension sharded end-to-end: cross-entropy uses
logsumexp + a one-hot contraction (no gather), so XLA reduces over the
sharded vocab with partial sums instead of all-gathering the logits.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.collectives import maybe_compress
from repro.models import registry
from repro.training import optimizer as opt

F32 = jnp.float32


def lm_loss(cfg, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    logits, extras = registry.apply_train(cfg, params, batch)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(F32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    # gold logit via an iota-compare masked reduce: fuses into one pass
    # over lg (the one-hot formulation materializes a [B,S,V] f32 buffer)
    # while the vocab dim stays sharded — no gather, no logits all-gather.
    iota_v = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.where(iota_v == targets[..., None], lg, 0.0).sum(-1)
    nll = (lse - gold).mean()
    loss = nll + extras["aux_loss"]
    return loss, {"nll": nll, "aux_loss": extras["aux_loss"]}


def make_train_step(cfg, opt_cfg: opt.OptConfig, *, num_microbatches: int = 1,
                    grad_compression: str = "none",
                    param_shardings=None,
                    accum_dtype=F32) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch["tokens"]: [B_global, S]; grad accumulation scans over
    num_microbatches splits of the batch (activation-memory bound).
    param_shardings: optional NamedSharding tree matching params — grads
    and their accumulators are constrained to it. Without the constraint
    XLA's propagation can leave the embedding/lm_head scatter-grad
    REPLICATED in f32 (a 4 GB/device buffer for a 200k vocab).
    """

    def _constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def loss_fn(params, mb):
        params = maybe_compress(params, grad_compression)
        return lm_loss(cfg, params, mb)

    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            def split(x):
                b = x.shape[0]
                m = num_microbatches
                return x.reshape(m, b // m, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                grads = _constrain(grads)
                g_acc = jax.tree.map(
                    lambda a, g: (a.astype(F32)
                                  + g.astype(F32) / num_microbatches
                                  ).astype(accum_dtype),
                    g_acc, grads)
                return (g_acc, l_acc + loss / num_microbatches), None

            g0 = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.zeros((), F32)),
                                            micro)
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads = _constrain(grads)

        new_params, new_opt, om = opt.apply_updates(
            opt_cfg, grads, opt_state, jnp.dtype(cfg.dtype))
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg) -> Callable:
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = registry.apply_prefill(cfg, params, batch, cache)
        return logits, new_cache
    return prefill_step


def make_decode_step(cfg) -> Callable:
    def decode_step(params, token, cache, pos):
        logits, new_cache, _ = registry.apply_decode(cfg, params, token,
                                                     cache, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return next_tok, logits, new_cache
    return decode_step
