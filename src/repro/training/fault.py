"""Fault tolerance: watchdog, straggler detection, retry-with-restore.

Run-time failure model at 1000+ nodes:

* **Hangs** (network partition, dead host in a collective): a `Watchdog`
  thread fires when no heartbeat lands within `timeout_s`; the callback
  can dump state, request a checkpoint, or abort the process so the
  cluster scheduler reschedules it.
* **Stragglers** (thermal throttling, bad HBM, noisy neighbour):
  `StepTimer` keeps a rolling window of step wall-times and flags steps
  slower than `k` x the window median. On a real cluster the event log
  feeds eviction policy; here it is surfaced in training metrics. The
  MTTR lever is checkpoint cadence, not in-step recovery — XLA collectives
  are synchronous, so a straggler *delays* but never corrupts a step.
* **Crashes**: `retry` re-runs a step function on transient errors with
  exponential backoff; combined with `CheckpointManager.restore_or` the
  training loop resumes from the last durable step (see launch/train.py).
* **Elasticity**: `elastic_mesh_shape` shrinks the data axis after
  permanent device loss; checkpoints store full logical arrays so
  `load_checkpoint(..., shardings=new)` reshard-restores onto the smaller
  (or larger) mesh with no format conversion.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import statistics
import threading
import time
from typing import Callable, Deque, List, Optional, Tuple, Union

from repro.common.transient import TransientError, is_transient

__all__ = [
    "StragglerEvent", "StepTimer", "Watchdog", "retry",
    "elastic_mesh_shape", "TransientError", "is_transient",
]

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float

    @property
    def slowdown(self) -> float:
        return self.duration_s / max(self.median_s, 1e-9)


class StepTimer:
    """Rolling step-time statistics + straggler flagging."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 warmup: int = 3):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.warmup = warmup
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._n = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._n += 1
        if self._n > self.warmup:  # skip compile steps
            if len(self.window) >= 5:
                med = statistics.median(self.window)
                if dt > self.threshold * med:
                    ev = StragglerEvent(step, dt, med)
                    self.events.append(ev)
                    log.warning("straggler: step %d took %.3fs (%.1fx median"
                                " %.3fs)", step, dt, ev.slowdown, med)
            self.window.append(dt)
        return dt

    def summary(self) -> dict:
        if not self.window:
            return {"steps_timed": self._n, "stragglers": len(self.events)}
        return {
            "steps_timed": self._n,
            "median_s": statistics.median(self.window),
            "p90_s": sorted(self.window)[int(0.9 * (len(self.window) - 1))],
            "stragglers": len(self.events),
            "worst_slowdown": max((e.slowdown for e in self.events),
                                  default=1.0),
        }


class Watchdog:
    """Fires `on_timeout` if `beat()` is not called within `timeout_s`.

    Used around blocking device work: a hung collective never returns, so
    only an external thread can observe it.
    """

    def __init__(self, timeout_s: float,
                 on_timeout: Callable[[], None],
                 poll_s: float = 0.5):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.poll_s = poll_s
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self) -> "Watchdog":
        self._thread.start()
        return self

    def beat(self) -> None:
        self._last = time.monotonic()

    def _run(self) -> None:
        while not self._stop.is_set():
            if time.monotonic() - self._last > self.timeout_s:
                if not self._fired:
                    self._fired = True
                    log.error("watchdog: no heartbeat for %.1fs",
                              self.timeout_s)
                    try:
                        self.on_timeout()
                    except Exception:  # noqa: BLE001 - never kill the thread
                        log.exception("watchdog callback failed")
            self._stop.wait(self.poll_s)

    @property
    def fired(self) -> bool:
        return self._fired

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


def retry(fn: Callable, *args, retries: int = 2, backoff_s: float = 0.5,
          transient: Union[Tuple[type, ...],
                           Callable[[BaseException], bool]] = is_transient,
          on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run `fn(*args)`, retrying transient failures with backoff.

    `transient` is either a tuple of exception types or a predicate; the
    default is the shared :func:`repro.common.is_transient` taxonomy, so
    programming errors (shape mismatches, donated handles, injected
    faults) fail fast instead of being retried with backoff — only
    failures expected under load (collective timeouts, OS errors, typed
    `TransientError`s) burn retry budget.

    `on_retry(attempt, exc)` runs before each retry — the hook where the
    launcher restores from the last checkpoint (device state after a
    failed collective is undefined; params must be reloaded).
    """
    if isinstance(transient, tuple):
        types = transient
        matches = lambda e: isinstance(e, types)  # noqa: E731
    else:
        matches = transient
    attempt = 0
    while True:
        try:
            return fn(*args)
        except Exception as e:  # noqa: PERF203, BLE001 - classified below
            if not matches(e):
                raise
            attempt += 1
            if attempt > retries:
                raise
            log.warning("transient failure (%s); retry %d/%d", e, attempt,
                        retries)
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(backoff_s * (2 ** (attempt - 1)))


def elastic_mesh_shape(n_devices: int, model_parallel: int,
                       pod: int = 1) -> Tuple[int, ...]:
    """Largest (pod, data, model) grid that fits surviving devices.

    The model axis is preserved (weights are sharded over it — shrinking
    it requires resharding weights, which the elastic checkpoint handles,
    but the *preferred* degradation is dropping data-parallel replicas).
    """
    if model_parallel <= 0 or n_devices < model_parallel:
        raise ValueError("not enough devices for the model-parallel group")
    data = n_devices // (model_parallel * pod)
    if data < 1:
        raise ValueError("not enough devices for one data replica")
    return (pod, data, model_parallel) if pod > 1 else (data, model_parallel)
