"""Deterministic, host-sharded synthetic LM data pipeline.

Production shape without the corpus: the pipeline yields token batches that
are (a) **deterministic in (seed, step)** — any host, any restart, any mesh
produces the same global batch, which is what makes checkpoint-resume and
elastic rescaling exact — and (b) **host-sharded** — each host materializes
only its slice of the global batch (`jax.process_index()`-aware), like a
tf.data/grain shard-by-process setup.

Two generators:

* ``synthetic``  — structured pseudo-text: a Zipf unigram backbone with
  planted bigram/trigram dependencies and repeated motifs, so a model
  trained on it has real signal to learn (loss decreases measurably, which
  the integration tests assert) and attention develops the concentrated
  score patterns HDP exploits.
* ``memorize``   — tiny fixed corpus cycled forever (overfit sanity checks).

The stateless ``batch_at(step)`` design (counter-based RNG, no generator
state to checkpoint) is the same trick production pipelines use for
reproducible restarts: the only data-state in a checkpoint is the step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"          # synthetic | memorize
    zipf_a: float = 1.2              # unigram skew
    n_motifs: int = 64               # planted repeated phrases
    motif_len: int = 8
    motif_rate: float = 0.15         # fraction of positions starting a motif
    bigram_rate: float = 0.5         # P(next token forced by bigram table)


class SyntheticLM:
    """Counter-based deterministic synthetic LM stream.

    ``batch_at(step)`` is a pure function of (cfg.seed, step) — no internal
    state. Per-host slicing happens at the caller via ``host_slice``.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipf unigram distribution over the vocab (stable across hosts).
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._unigram = p / p.sum()
        # Deterministic bigram successor table: token t -> successor(t).
        self._bigram = base.integers(0, v, size=v, dtype=np.int64)
        # Motif bank: short phrases that repeat verbatim (gives attention
        # long-range copy structure — the concentrated q-k pairs HDP prunes
        # toward).
        self._motifs = base.integers(
            0, v, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int64)

    def batch_at(self, step: int) -> np.ndarray:
        """Global batch [global_batch, seq_len] int32 for this step."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xDA7A]))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = rng.choice(v, size=(B, S), p=self._unigram).astype(np.int64)

        # Plant bigram dependencies: with prob bigram_rate, position i+1 is
        # the deterministic successor of position i.
        use_bg = rng.random((B, S - 1)) < cfg.bigram_rate
        for i in range(S - 1):
            nxt = self._bigram[toks[:, i]]
            toks[:, i + 1] = np.where(use_bg[:, i], nxt, toks[:, i + 1])

        # Plant motifs: overwrite a few spans with repeated phrases; the
        # same motif id repeats within a row (copy task).
        n_spans = max(1, int(S * cfg.motif_rate / cfg.motif_len))
        starts = rng.integers(0, max(S - cfg.motif_len, 1), size=(B, n_spans))
        motif_ids = rng.integers(0, cfg.n_motifs, size=(B,))
        for b in range(B):
            m = self._motifs[motif_ids[b]]
            for s0 in starts[b]:
                toks[b, s0:s0 + cfg.motif_len] = m[: S - s0]
        return toks.astype(np.int32)


class MemorizeLM:
    """Fixed tiny corpus, cycled — for overfit/regression tests."""

    def __init__(self, cfg: DataConfig, corpus_rows: int = 16):
        rng = np.random.default_rng(cfg.seed)
        self.cfg = cfg
        self._corpus = rng.integers(
            0, cfg.vocab_size, size=(corpus_rows, cfg.seq_len),
            dtype=np.int64).astype(np.int32)

    def batch_at(self, step: int) -> np.ndarray:
        B = self.cfg.global_batch
        n = self._corpus.shape[0]
        idx = (np.arange(B) + step * B) % n
        return self._corpus[idx]


def make_source(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "memorize":
        return MemorizeLM(cfg)
    raise ValueError(f"unknown data kind {cfg.kind!r}")


def host_slice(global_batch: int,
               process_index: Optional[int] = None,
               process_count: Optional[int] = None) -> slice:
    """Rows of the global batch this host materializes."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    if global_batch % pc:
        # Uneven host split: host 0 takes the remainder (rare; documented).
        per = global_batch // pc
        extra = global_batch - per * pc
        start = pi * per + min(pi, extra)
        return slice(start, start + per + (1 if pi < extra else 0))
    per = global_batch // pc
    return slice(pi * per, (pi + 1) * per)


class Prefetcher:
    """Background-thread prefetch of host-local batches (depth-N pipeline).

    Overlaps the (numpy) batch synthesis/IO with device compute — the
    host-side half of compute/comm overlap. ``close()`` is idempotent.
    """

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 sl: Optional[slice] = None):
        self._source = source
        self._sl = sl if sl is not None else host_slice(
            source.cfg.global_batch)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)[self._sl]
            item = (step, {"tokens": batch})
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
