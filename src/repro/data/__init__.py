from repro.data.pipeline import (  # noqa: F401
    DataConfig, MemorizeLM, Prefetcher, SyntheticLM, host_slice, make_source)
