"""Block-sparse FUM attention kernel — the TPU analogue of the paper's
Fetch-Upon-Mask dataflow.

Scalar-prefetched per-(head, q-block) lists of surviving KV block indices
drive the K/V BlockSpec index_maps, so pruned blocks are NEVER DMA'd from
HBM — the memory-access saving the HDP co-processor gets from its mask
registers. Scores on surviving blocks use the paper's approximation
QK^T - FQ FK^T (fractional parts recomputed on the VPU via trunc, costing
no extra HBM traffic). Early-pruned heads skip all compute via a
prefetched head gate.

The grid is (B*H, nq, max_keep) — static shape, so rows keeping more than
max_keep blocks drop their lowest-importance extras (quantified in
benchmarks; exact when max_keep = nk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

F32 = jnp.float32
NEG = -1e30


def _kernel(idx_ref, cnt_ref, head_ref, sscale_ref, len_ref,  # scalar prefetch
            q_ref, k_ref, v_ref, o_ref,            # tensors
            acc_ref, m_ref, l_ref,                 # scratch
            *, scale, causal, approx, block_q, block_k, max_keep):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    active = (j < cnt_ref[b, i]) & (head_ref[b] > 0)

    @pl.when(active)
    def _body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)
        if approx:
            fq = q - jnp.trunc(q)
            fk = k - jnp.trunc(k)
            s = s - jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                        preferred_element_type=F32)
        # static 1/sqrt(hd) plus the dynamic calibration rescale 1/(s_q s_k)
        s = s * (scale * sscale_ref[0])
        kv_blk = idx_ref[b, i, j]
        rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = kv_blk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = cols < len_ref[b]
        if causal:
            valid = valid & (rows >= cols)
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == max_keep - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = acc_ref[...] / l
        gate = (head_ref[b] > 0).astype(F32)   # pruned head -> zeros
        o_ref[0] = (out * gate).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "approx", "block_q", "block_k", "interpret"))
def hdp_block_sparse_attention(q, k, v, kv_idx, counts, head_kept, *,
                               causal: bool = True, approx: bool = True,
                               block_q: int = 128, block_k: int = 128,
                               score_scale=None, kv_len=None,
                               interpret: bool = False):
    """q,k,v [B,H,S,hd]; kv_idx [B,H,nq,max_keep] int32; counts [B,H,nq];
    head_kept [B,H] (bool/int); score_scale: optional calibration rescale
    1/(s_q*s_k) applied to scores; kv_len [B,H] optional per-row valid KV
    length (serving decode: cache positions beyond the current token are
    masked — defaults to the full Sk). Returns [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    sqp = -(-Sq // block_q) * block_q
    skp = -(-Sk // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - Sq), (0, 0))
                 ).reshape(B * H, sqp, hd)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - Sk), (0, 0))
                 ).reshape(B * H, skp, hd)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - Sk), (0, 0))
                 ).reshape(B * H, skp, hd)
    nq = sqp // block_q
    max_keep = kv_idx.shape[-1]
    idx = kv_idx.reshape(B * H, nq, max_keep).astype(jnp.int32)
    cnt = counts.reshape(B * H, nq).astype(jnp.int32)
    hk = head_kept.reshape(B * H).astype(jnp.int32)
    ss = jnp.asarray(1.0 if score_scale is None else score_scale,
                     F32).reshape(1)
    lens = (jnp.full((B * H,), Sk, jnp.int32) if kv_len is None
            else kv_len.reshape(B * H).astype(jnp.int32))

    kernel = functools.partial(
        _kernel, scale=1.0 / (hd ** 0.5), causal=causal, approx=approx,
        block_q=block_q, block_k=block_k, max_keep=max_keep)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(B * H, nq, max_keep),
        in_specs=[
            pl.BlockSpec((1, block_q, hd),
                         lambda b, i, j, idx, c, h, s, le: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, idx, c, h, s, le: (b, idx[b, i, j], 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, idx, c, h, s, le: (b, idx[b, i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda b, i, j, idx, c, h, s, le: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), F32),
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, 1), F32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, sqp, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(idx, cnt, hk, ss, lens, qp, kp, vp)
    return out.reshape(B, H, sqp, hd)[:, :, :Sq]
