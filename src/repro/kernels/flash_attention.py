"""Dense flash attention Pallas TPU kernel (the paper's dense baseline).

Grid (B*H, nq, nk), innermost kv dim sequential with online-softmax
accumulators in VMEM scratch — the canonical TPU tiling: q block stays
resident, K/V blocks stream HBM->VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

F32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, causal, block_q, block_k, nk, sq, sk):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly in the future of the whole q tile
    run = (not causal) or (j * block_k <= i * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = cols < sk
        if causal:
            valid = valid & (rows >= cols)
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v [B,H,S,hd] -> [B,H,S,hd]."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    sqp = -(-Sq // block_q) * block_q
    skp = -(-Sk // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - Sk), (0, 0)))
    qr = qp.reshape(B * H, sqp, hd)
    kr = kp.reshape(B * H, skp, hd)
    vr = vp.reshape(B * H, skp, hd)
    nq, nk = sqp // block_q, skp // block_k

    kernel = functools.partial(
        _kernel, scale=1.0 / (hd ** 0.5), causal=causal, block_q=block_q,
        block_k=block_k, nk=nk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), F32),
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, 1), F32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, sqp, hd)[:, :, :Sq]
