"""Pallas TPU kernels for the HDP hot spots + dense baseline.

Validated in interpret mode on CPU; compiled natively on TPU.
"""
from repro.kernels.compat import tpu_compiler_params
from repro.kernels.ops import flash, hdp_attention_tpu

__all__ = ["flash", "hdp_attention_tpu", "tpu_compiler_params"]
