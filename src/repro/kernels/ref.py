"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blocking
from repro.core.quant import int_frac_split

F32 = jnp.float32
NEG = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q,k,v [B,H,S,hd] -> [B,H,S,hd], exact softmax attention."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), k.astype(F32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[2], k.shape[2]), bool))
        s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32)).astype(q.dtype)


def hdp_scout_ref(iq, ik, *, block_q: int, block_k: int, rho_b: float,
                  causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]:
    """Integer scout oracle.

    iq/ik [B,H,S,hd] (integer-valued floats). Returns
    (theta [B,H,nq,nk], keep mask bool [B,H,nq,nk], theta_head [B,H]).
    """
    s = jnp.einsum("bhqd,bhkd->bhqk", iq.astype(F32), ik.astype(F32))
    lq, lk = iq.shape[2], ik.shape[2]
    valid = None
    if causal:
        valid = blocking.causal_element_mask(lq, lk)
        s = jnp.where(valid, s, 0.0)
    theta = blocking.block_abs_sum(s, block_q, block_k)
    bvalid = None
    if causal:
        bvalid = blocking.block_abs_sum(
            valid.astype(F32), block_q, block_k) > 0
    thr = blocking.row_threshold(theta, rho_b, bvalid)
    keep = blocking.block_keep_mask(theta, thr, bvalid)
    theta_head = jnp.where(bvalid, theta, 0.0).sum((-2, -1)) if causal \
        else theta.sum((-2, -1))
    return theta, keep, theta_head


def hdp_block_attn_ref(q, k, v, keep, *, block_q: int, block_k: int,
                       causal: bool = True, approx: bool = True,
                       head_kept=None) -> jnp.ndarray:
    """Block-sparse approximate attention oracle.

    q,k,v [B,H,S,hd]; keep bool [B,H,nq,nk]. Scores on surviving blocks are
    QK^T - FQ FK^T (the paper's 3-term approximation); pruned blocks are
    excluded from the softmax; pruned heads (head_kept [B,H] bool) output 0.
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    qf = q.astype(F32)
    kf = k.astype(F32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if approx:
        _, fq = int_frac_split(qf)
        _, fk = int_frac_split(kf)
        s = s - jnp.einsum("bhqd,bhkd->bhqk", fq, fk)
    s = s * scale
    keep_e = blocking.expand_block_mask(keep, block_q, block_k)
    if causal:
        keep_e = jnp.logical_and(
            keep_e, blocking.causal_element_mask(q.shape[2], k.shape[2]))
    p = blocking.masked_softmax(s, keep_e)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32))
    if head_kept is not None:
        out = out * head_kept[..., None, None].astype(F32)
    return out.astype(q.dtype)


def keep_mask_to_indices(keep, theta, max_keep: int):
    """Convert a keep mask to (indices [.., nq, max_keep], counts [.., nq]).

    Rows keeping more than max_keep blocks drop their lowest-theta extras
    (sorted selection — the static-shape compromise of the TPU kernel;
    deviation measured in benchmarks). Padded entries point at block 0.
    """
    score = jnp.where(keep, theta, -jnp.inf)
    order = jnp.argsort(-score, axis=-1)[..., :max_keep]       # desc by theta
    sorted_keep = jnp.take_along_axis(keep, order, axis=-1)
    counts = sorted_keep.sum(-1).astype(jnp.int32)
    idx = jnp.where(sorted_keep, order, 0).astype(jnp.int32)
    # kernel iterates j < count, so re-sort kept indices ascending for
    # monotone DMA access
    key = jnp.where(sorted_keep, idx, jnp.iinfo(jnp.int32).max)
    idx = jnp.sort(key, axis=-1)
    idx = jnp.where(jnp.arange(max_keep) < counts[..., None], idx, 0)
    return idx, counts
