"""jit'd dispatch wrappers: the full TPU HDP attention pipeline.

``hdp_attention_tpu`` chains the three hardware stages exactly like the
co-processor's workflow (Sec. IV-A):
  1. integer scout kernel (PE array + Sparsity Engine) -> theta, keep mask
  2. early head gate from theta_head (vs tau_H)
  3. FUM block-sparse attention kernel on surviving blocks/heads

``interpret=None`` auto-selects interpret mode off-TPU so the same code
path runs in CI (CPU) and production (TPU).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import HDPConfig
from repro.core.quant import calib_scale, quantize_fixed
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hdp_block_attn import hdp_block_sparse_attention
from repro.kernels.hdp_scout import hdp_scout
from repro.kernels.ref import keep_mask_to_indices

F32 = jnp.float32


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash(q, k, v, *, causal: bool = True, block_q: int = 128,
          block_k: int = 128, interpret: Optional[bool] = None):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k,
                           interpret=_auto_interpret(interpret))


def hdp_attention_tpu(q, k, v, cfg: HDPConfig, *,
                      max_keep: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      return_stats: bool = False):
    """Full HDP pipeline on TPU tiles. q,k,v [B,H,S,hd].

    max_keep: static cap on kept blocks per row (None -> exact, = nk).
    """
    interpret = _auto_interpret(interpret)
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq, bk = cfg.block_q, cfg.block_k
    nk = -(-Sk // bk)

    sq = calib_scale(q, cfg.int_bits, cfg.calib)
    sk = calib_scale(k, cfg.int_bits, cfg.calib)
    qq = quantize_fixed(q.astype(F32) * sq, cfg.int_bits, cfg.frac_bits)
    kq = quantize_fixed(k.astype(F32) * sk, cfg.int_bits, cfg.frac_bits)
    iq = jnp.trunc(qq)
    ik = jnp.trunc(kq)

    theta, keep, theta_head = hdp_scout(
        iq, ik, rho_b=cfg.rho_b, block_q=bq, block_k=bk,
        causal=cfg.causal, interpret=interpret)
    if not cfg.block_pruning:
        keep = jnp.ones_like(keep)

    if cfg.normalize_head_score:
        if cfg.causal:
            n_valid = 0.5 * Sq * (Sq + 1) if Sq == Sk else Sq * Sk
        else:
            n_valid = Sq * Sk
        theta_head = theta_head / max(float(n_valid), 1.0)
    head_kept = (theta_head > cfg.tau_h) if cfg.head_pruning \
        else jnp.ones_like(theta_head, bool)

    mk = max_keep or nk
    kv_idx, counts = keep_mask_to_indices(keep, theta, mk)

    out = hdp_block_sparse_attention(
        qq, kq, v, kv_idx, counts, head_kept,
        causal=cfg.causal, approx=cfg.approx, block_q=bq, block_k=bk,
        score_scale=1.0 / (sq * sk), interpret=interpret)

    if not return_stats:
        return out, None
    nvalid_blocks = keep.shape[-2] * keep.shape[-1]
    stats = {
        "block_sparsity": 1.0 - keep.mean(dtype=F32),
        "head_sparsity": 1.0 - head_kept.astype(F32).mean(),
        "kept_blocks_per_row": counts.mean(dtype=F32),
        "theta_head": theta_head,
        "total_blocks": nvalid_blocks,
    }
    return out, stats
