"""Pallas-TPU API compatibility across JAX versions.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` upstream
(jax-ml/jax #21523 lineage); depending on the pinned JAX, exactly one of
the two names exists. Every kernel in this package goes through
:func:`tpu_compiler_params` so the repo runs on either side of the rename.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

#: The compiler-params class available in the running JAX.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this JAX exposes."""
    return CompilerParams(**kwargs)
