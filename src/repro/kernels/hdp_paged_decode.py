"""Gather-free paged FUM decode kernel — page-table-native Fetch-Upon-Mask.

The block-sparse kernel in ``hdp_block_attn`` consumes contiguous K/V, so
the paged serving path had to gather surviving pages into a dense slab
first — O(B*Sk) memory traffic regardless of how many pages the scout
pruned. This kernel removes the gather entirely: the *page pool* is the
kernel input, and scalar-prefetched per-row lists of surviving pool page
ids drive the K/V BlockSpec index maps. A pruned page's id never appears
in the list, so its HBM is never DMA'd — the paper's co-processor
dataflow, now honored at the memory system level for serving decode.

Grid is (B, N, max_keep): one batch row x kv head per program, streaming
that row's kept pages in ascending logical order (monotone DMA). The G
query heads of a GQA group AND the Sq query rows of a multi-query verify
call ride in the block's sublane dim and share the page stream — a
speculative-verify round reads each surviving page ONCE for all Sq rows
instead of once per token, which is the round's bandwidth win. Per-row
keep masks and KV extents still apply inside the softmax: verify rows
sit at consecutive positions, so row ``r``'s valid extent is the base
``kv_len`` plus its query index (``r % Sq``) — no extra prefetch array.

Two pool formats:

* fp32 pool — K arrives full-precision and is snapped to the fixed-point
  grid on the VPU (trunc/round cost no extra HBM traffic), matching the
  write-time-quantized semantics of the XLA stage exactly.
* int8 pool (``k_scale``/``v_scale`` passed) — pages arrive as int8
  codes (4x less DMA per surviving page) and are dequantized IN REGISTER
  from scalar-prefetched per-page scales; the decoded values land
  exactly on the fixed-point grid, so no re-snap is needed and the
  scores match the XLA dequant path bit for bit (power-of-two scales
  commute exactly with the dots). The -128 poison sentinel decodes to
  NaN (tripwire), and a NaN page scale poisons the whole page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.quant import POISON_CODE, int_frac_split, quantize_fixed
from repro.kernels.compat import tpu_compiler_params

F32 = jnp.float32
NEG = -1e30


def _kernel(pid_ref, logical_ref, cnt_ref, len_ref,   # scalar prefetch
            q_ref, k_ref, v_ref, keep_ref, o_ref,     # tensors
            acc_ref, m_ref, l_ref,                    # scratch
            *, scale, approx, int_bits, frac_bits, ps, max_keep, n_q,
            kscl_ref=None, vscl_ref=None):
    b = pl.program_id(0)
    n = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j < cnt_ref[b])
    def _body():
        rows = q_ref.shape[2] * q_ref.shape[3]        # G * Sq
        q = q_ref[0, 0].reshape(rows, -1).astype(F32)  # [G*Sq, hd] fixed grid
        if kscl_ref is None:
            # fp32 pool: snap the full-precision page to the write-time
            # scout's grid on the VPU (the shared core.quant ops are
            # plain jnp — safe here)
            k = k_ref[0, :, 0].astype(F32)            # [ps, hd] pool page
            kq = quantize_fixed(k, int_bits, frac_bits)
            v = v_ref[0, :, 0]
        else:
            # int8 pool: dequantize in register from the prefetched
            # per-page scale — decoded values already sit on the grid
            kc = k_ref[0, :, 0]                       # [ps, hd] int8 codes
            ks = kscl_ref[pid_ref[b, j], n]
            kq = jnp.where(kc == POISON_CODE, jnp.nan, kc.astype(F32)) * ks
            v = v_ref[0, :, 0].astype(F32) * vscl_ref[pid_ref[b, j], n]
        s = jax.lax.dot_general(q, kq, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)
        if approx:
            fq = int_frac_split(q)[1]
            fk = int_frac_split(kq)[1]
            s = s - jax.lax.dot_general(fq, fk, (((1,), (1,)), ((), ())),
                                        preferred_element_type=F32)
        s = s * scale
        cols = logical_ref[b, j] * ps + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        # per-row KV extent: verify rows are consecutive positions, so
        # row r (query index r % Sq) extends the base length by r % Sq
        sq_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % n_q
        valid = cols < (len_ref[b] + sq_idx)
        valid = valid & (keep_ref[0, 0, 0].reshape(rows) > 0)[:, None]
        s = jnp.where(valid, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == max_keep - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).reshape(o_ref.shape[2:]).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "approx", "int_bits", "frac_bits", "interpret"))
def hdp_paged_fum_decode(qq, k_pool, v_pool, page_ids, logical, counts,
                         keep, kv_len, *, approx: bool = True,
                         int_bits: int = 4, frac_bits: int = 12,
                         k_scale=None, v_scale=None,
                         interpret: bool = False):
    """qq [B,N,G,Sq,hd] fixed-grid queries (Sq = 1 for plain decode, > 1
    for the speculative multi-query verify); k/v_pool [P,ps,N,hd] page
    pools; page_ids/logical [B,mk] int32 (pool id / slot position of each
    kept page — the union over query rows, scratch-0-padded past counts);
    counts [B] int32 kept pages per row; keep [B,mk,N,G,Sq] int32
    per-query-row keep; kv_len [B] int32 valid KV extent of query row 0
    (row j's extent is kv_len + j: verify rows are consecutive
    positions). ``k_scale``/``v_scale`` [P,N] fp32 mark a quantized pool
    (int8 codes + per-page scales, dequantized in register from scalar
    prefetch). Returns [B,N,G,Sq,hd] (head gate applied by the caller).
    Pages absent from ``page_ids`` are never read.
    """
    B, N, G, Sq, hd = qq.shape
    _, ps, _, _ = k_pool.shape
    mk = page_ids.shape[1]
    quantized = k_scale is not None
    base = functools.partial(
        _kernel, scale=1.0 / (hd ** 0.5), approx=approx, int_bits=int_bits,
        frac_bits=frac_bits, ps=ps, max_keep=mk, n_q=Sq)

    # scalar-prefetch operands: the page lists driving the BlockSpec
    # index maps, plus (quantized pools) the per-page scales the kernel
    # body reads at dequant time. Prefetch refs arrive positionally ahead
    # of the tensor refs, so the quantized wrapper peels the two scale
    # refs off into the keyword slots; the index-map lambdas take one ref
    # per prefetch operand after the grid indices.
    if quantized:
        n_pref = 6

        def kernel(pid, lg, c, le, ks, vs, *refs):
            return base(pid, lg, c, le, *refs, kscl_ref=ks, vscl_ref=vs)

        def imap(fn):
            return lambda b, n, j, pid, lg, c, le, ks, vs: fn(b, n, j, pid)
    else:
        n_pref = 4
        kernel = base

        def imap(fn):
            return lambda b, n, j, pid, lg, c, le: fn(b, n, j, pid)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pref,
        grid=(B, N, mk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Sq, hd),
                         imap(lambda b, n, j, pid: (b, n, 0, 0, 0))),
            pl.BlockSpec((1, ps, 1, hd),
                         imap(lambda b, n, j, pid: (pid[b, j], 0, n, 0))),
            pl.BlockSpec((1, ps, 1, hd),
                         imap(lambda b, n, j, pid: (pid[b, j], 0, n, 0))),
            pl.BlockSpec((1, 1, 1, G, Sq),
                         imap(lambda b, n, j, pid: (b, j, n, 0, 0))),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Sq, hd),
                               imap(lambda b, n, j, pid: (b, n, 0, 0, 0))),
        scratch_shapes=[
            pltpu.VMEM((G * Sq, hd), F32),
            pltpu.VMEM((G * Sq, 1), F32),
            pltpu.VMEM((G * Sq, 1), F32),
        ],
    )
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, N, G, Sq, hd), qq.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    if quantized:
        return call(page_ids, logical, counts, kv_len,
                    k_scale.astype(F32), v_scale.astype(F32),
                    qq, k_pool, v_pool, keep)
    return call(page_ids, logical, counts, kv_len, qq, k_pool, v_pool, keep)
