"""HDP integer scout kernel: Integer_Q x Integer_K^T -> block importances,
row-balanced thresholds and keep masks — the paper's PE-array importance
accumulation + Sparsity Engine, fused into one Pallas kernel.

Grid (B*H, nq, nkc): each step multiplies one q tile (the pruning block
row) against a CHUNK of ck KV blocks, pools |scores| per block into a VMEM
theta row; the last chunk computes Theta_i (Alg. 2 line 15) from the full
row and emits the keep mask. Block validity (causal + seq bounds) is
analytic — no data-dependent bookkeeping, matching the ASIC's END_R flag.

The scout reads only integer parts: on TPU these are int8-representable,
so HBM traffic for this pass is ~4x less than the bf16 QK^T it replaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

F32 = jnp.float32
BIG = 1e30


def _kernel(iq_ref, ik_ref, theta_ref, mask_ref, trow_ref,
            *, rho_b, causal, block_q, block_k, chunk_blocks, nk, nkc,
            sq_true, sk_true):
    i = pl.program_id(1)
    j = pl.program_id(2)
    ck, bk = chunk_blocks, block_k

    # ---- theta for this chunk of blocks (PE-array importance) ----
    iq = iq_ref[0].astype(F32)
    ik = ik_ref[0].astype(F32)
    s = jax.lax.dot_general(iq, ik, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)  # [bq, ck*bk]
    rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = j * ck * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (cols < sk_true) & (rows < sq_true)
    if causal:
        valid = valid & (rows >= cols)
    s = jnp.where(valid, jnp.abs(s), 0.0)
    theta_chunk = s.reshape(block_q, ck, bk).sum(axis=(0, 2))  # [ck]
    trow_ref[0, pl.ds(j * ck, ck)] = theta_chunk

    # ---- Sparsity Engine: threshold + mask once the row is complete ----
    @pl.when(j == nkc - 1)
    def _finish():
        trow = trow_ref[0, :]                                  # [nk_pad]
        bcols = jax.lax.iota(jnp.int32, trow.shape[0]) * bk    # block start
        bvalid = bcols < sk_true
        if causal:
            bvalid = bvalid & (bcols <= i * block_q + block_q - 1)
        bvalid = bvalid & (jax.lax.iota(jnp.int32, trow.shape[0]) < nk)
        cnt = jnp.maximum(bvalid.sum().astype(F32), 1.0)
        tmin = jnp.where(bvalid, trow, BIG).min()
        tmax = jnp.where(bvalid, trow, -BIG).max()
        tmean = jnp.where(bvalid, trow, 0.0).sum() / cnt
        if rho_b >= 0:
            thr = rho_b * tmax + (1.0 - rho_b) * tmean
        else:
            thr = -rho_b * tmin + (1.0 + rho_b) * tmean
        keep = (trow >= thr) & bvalid
        theta_ref[0, 0, :] = jnp.where(bvalid, trow, 0.0)
        mask_ref[0, 0, :] = keep.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rho_b", "block_q", "block_k",
                                             "causal", "chunk_blocks",
                                             "interpret"))
def hdp_scout(iq, ik, *, rho_b: float, block_q: int = 128,
              block_k: int = 128, causal: bool = True,
              chunk_blocks: int = 8, interpret: bool = False):
    """iq/ik [B,H,S,hd] integer-valued -> (theta, keep, theta_head).

    theta [B,H,nq,nk] f32; keep bool [B,H,nq,nk]; theta_head [B,H].
    """
    B, H, Sq, hd = iq.shape
    Sk = ik.shape[2]
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    ck = max(1, min(chunk_blocks, nk))
    nkc = -(-nk // ck)
    skp = nkc * ck * block_k
    sqp = nq * block_q
    nk_pad = -(-(nkc * ck) // 128) * 128

    iqp = jnp.pad(iq, ((0, 0), (0, 0), (0, sqp - Sq), (0, 0))
                  ).reshape(B * H, sqp, hd)
    ikp = jnp.pad(ik, ((0, 0), (0, 0), (0, skp - Sk), (0, 0))
                  ).reshape(B * H, skp, hd)

    kernel = functools.partial(
        _kernel, rho_b=rho_b, causal=causal, block_q=block_q,
        block_k=block_k, chunk_blocks=ck, nk=nk, nkc=nkc,
        sq_true=Sq, sk_true=Sk)
    theta, mask = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nkc),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, ck * block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nk_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, 1, nk_pad), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nq, nk_pad), F32),
            jax.ShapeDtypeStruct((B * H, nq, nk_pad), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, nk_pad), F32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(iqp, ikp)

    theta = theta[:, :, :nk].reshape(B, H, nq, nk)
    keep = mask[:, :, :nk].reshape(B, H, nq, nk) > 0
    theta_head = theta.sum((-2, -1))
    return theta, keep, theta_head
