"""Mixture-of-Experts FFN with expert parallelism (GShard-style dropping).

Dispatch/combine are expressed as one-hot einsums so the XLA SPMD
partitioner emits all-to-alls when the expert dim is sharded over `model`.
Capacity-factor token dropping bounds the expert buffers (required for a
static-shape TPU program). Supports top-k routing (olmoe: 64e top-8) and a
shared always-on expert (llama4-scout: 16e top-1 + shared).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L

F32 = jnp.float32


def moe_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": L.dense_init(L.key_for(rng, "router"), (d, e), dtype),
        "w_gate": L.dense_init(L.key_for(rng, "w_gate"), (e, d, f), dtype, in_axis=1),
        "w_up": L.dense_init(L.key_for(rng, "w_up"), (e, d, f), dtype, in_axis=1),
        "w_down": L.dense_init(L.key_for(rng, "w_down"), (e, f, d), dtype, in_axis=1),
    }
    s = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": L.dense_init(L.key_for(rng, "sh_gate"), (d, fs), dtype),
            "w_up": L.dense_init(L.key_for(rng, "sh_up"), (d, fs), dtype),
            "w_down": L.dense_init(L.key_for(rng, "sh_down"), (fs, d), dtype),
        }
        s["shared"] = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                       "w_down": ("mlp", "embed")}
    return p, s


def moe_apply(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar).

    GShard-style *grouped* dispatch: the sequence is split into groups of
    <= moe_group tokens and capacity is enforced per group. The dispatch/
    combine tensors are [B,G,Sg,E,Cg] — their footprint shrinks by the
    group count vs. the ungrouped [B,S,E,C] (which is 5+ GB/device at
    S=32k prefill). Grouping is also what production MoE stacks do: it
    bounds router skew locally and keeps the all-to-all chunks small.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_active
    # Grouping is a *memory* trade (it adds routing/collective structure,
    # measured to hurt when unneeded — llama4 lt 45 s -> 287 s): apply it
    # only when the ungrouped [S,E,C] dispatch would be big (olmoe-style
    # many-expert models / 32k prefill, where it is quadratic in S).
    cap0 = max(1, int(cfg.capacity_factor * S * K / E))
    if E < 32 or S * E * cap0 <= 64 * 2 ** 20:
        # few-expert models (llama4: E=16) never need it — sequence
        # sharding already splits the modest [S,E,C] dispatch, and
        # grouping there was measured to *hurt* (prefill peak 10 -> 18 GB)
        Sg = S
    else:
        # group count >= 16 when S allows: the group dim inherits the
        # sequence sharding; fewer groups than the `model` axis size
        # replicate the dispatch tensors
        Sg = min(getattr(cfg, "moe_group", 2048), max(S // 16, 128), S)
    while S % Sg:
        Sg //= 2
    G = S // Sg
    capacity = max(1, int(cfg.capacity_factor * Sg * K / E))
    xg = x.reshape(B, G, Sg, D)

    logits = jnp.einsum("bgsd,de->bgse", xg, p["router"],
                        preferred_element_type=F32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [B,G,Sg,E]
    top_p, top_i = jax.lax.top_k(probs, K)                      # [B,G,Sg,K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    onehot_e = jax.nn.one_hot(top_i, E, dtype=F32)              # [B,G,Sg,K,E]

    # position of each (token, slot) inside its expert buffer, s-major
    flat = onehot_e.reshape(B, G, Sg * K, E)
    pos = jnp.cumsum(flat, axis=2) - flat                       # [B,G,Sg*K,E]
    pos = (pos * flat).sum(-1).reshape(B, G, Sg, K).astype(jnp.int32)
    fits = pos < capacity
    onehot_c = jax.nn.one_hot(pos, capacity, dtype=F32) * fits[..., None]

    # dispatch/combine [B,G,Sg,E,C]
    dispatch = jnp.einsum("bgske,bgskc->bgsec", onehot_e, onehot_c)
    combine = jnp.einsum("bgske,bgskc,bgsk->bgsec", onehot_e, onehot_c,
                         top_p)

    xin = jnp.einsum("bgsec,bgsd->bgecd", dispatch.astype(x.dtype), xg,
                     preferred_element_type=F32).astype(x.dtype)
    xin = shd(xin, "batch", "seq_act", "experts_act", None, None)
    if cfg.act == "silu_glu":
        h = jax.nn.silu(jnp.einsum("bgecd,edf->bgecf", xin, p["w_gate"])) \
            * jnp.einsum("bgecd,edf->bgecf", xin, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bgecd,edf->bgecf", xin, p["w_gate"]),
                        approximate=True)
    h = shd(h, "batch", "seq_act", "experts_act", None, None)
    xout = jnp.einsum("bgecf,efd->bgecd", h, p["w_down"])
    y = jnp.einsum("bgsec,bgecd->bgsd", combine.astype(x.dtype), xout,
                   preferred_element_type=F32).astype(x.dtype)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]

    # GShard load-balancing aux loss: E * sum_e f_e * P_e
    f_e = onehot_e.sum(3).mean(axis=(0, 1, 2))                  # routed fraction
    p_e = probs.mean(axis=(0, 1, 2))
    aux = E * jnp.sum(f_e * p_e) * cfg.router_aux_weight
    return y, aux
