"""Mamba2 (SSD) layer — used by the zamba2 hybrid.

State-space recurrence per head: S[P,N] updated as
``S_t = exp(dt_t A) S_{t-1} + dt_t x_t (x) B_t``, output ``y_t = S_t C_t``.
Attention-free: HDP does not apply to these blocks (DESIGN.md
§Arch-applicability). Causal depthwise conv (width 4) on the input branch.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

F32 = jnp.float32


def d_inner(cfg) -> int:
    return 2 * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def layer_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    d, di, n, h = cfg.d_model, d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    p = {
        "Wz": L.dense_init(L.key_for(rng, "Wz"), (d, di), dtype),
        "Wx": L.dense_init(L.key_for(rng, "Wx"), (d, di), dtype),
        "WB": L.dense_init(L.key_for(rng, "WB"), (d, n), dtype),
        "WC": L.dense_init(L.key_for(rng, "WC"), (d, n), dtype),
        "Wdt": L.dense_init(L.key_for(rng, "Wdt"), (d, h), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "A_log": jnp.zeros((h,), F32),
        "D_skip": jnp.ones((h,), dtype),
        "conv_w": 0.1 * jnp.ones((cfg.ssm_conv, di), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "Wo": L.dense_init(L.key_for(rng, "Wo"), (di, d), dtype),
    }
    s = {
        "Wz": ("embed", "mlp"), "Wx": ("embed", "mlp"),
        "WB": ("embed", "state"), "WC": ("embed", "state"),
        "Wdt": ("embed", "heads"), "dt_bias": ("heads",),
        "A_log": ("heads",), "D_skip": ("heads",),
        "conv_w": ("conv", "mlp"), "norm_w": ("mlp",),
        "Wo": ("mlp", "embed"),
    }
    return p, s


def _causal_conv(x, w, conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv via shifted adds. x [B,T,di]; w [W,di].

    conv_state: [B,W-1,di] trailing inputs from the previous segment (or
    zeros). Returns (y, new_conv_state)."""
    W = w.shape[0]
    B, T, di = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)  # [B, T+W-1, di]
    y = sum(xp[:, i : i + T] * w[i] for i in range(W))
    new_state = jax.lax.dynamic_slice_in_dim(xp, xp.shape[1] - (W - 1), W - 1, 1)
    return y, new_state


def _ssd_scan(xh, dt, decay, Bm, Cm, s0):
    """Per-timestep reference recurrence (oracle; O(T) sequential).

    xh [B,T,H,P]; dt,decay [B,T,H]; Bm,Cm [B,T,N]; s0 [B,H,P,N]."""
    def step(S, xs):
        xt, dtt, at, bt, ct = xs
        S = at[..., None, None] * S + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", S, ct)
        return S, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(decay, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0))
    S, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), S


def _ssd_chunked(xh, dt, log_decay, Bm, Cm, s0, chunk: int):
    """SSD chunked dual form (Mamba2's own parallel algorithm).

    Processes T in chunks of L: intra-chunk contributions are an O(L^2)
    masked matmul (MXU-friendly), the state is carried across chunks —
    the per-timestep scan saves [T,B,H,P,N] carries for the backward
    pass (7.5 GB/layer at T=4k for zamba2), the chunked form saves only
    [T/L,...]. Decay ratios use log-space cumsums (dt*A <= 0, so every
    exp() argument is <= 0 — no overflow).

    xh [B,T,H,P]; dt [B,T,H]; log_decay = dt*A [B,T,H] (<= 0);
    Bm,Cm [B,T,N]; s0 [B,H,P,N]. Returns (y [B,T,H,P], S_final).
    """
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    if T % L:
        raise ValueError(f"T={T} not divisible by ssd chunk {L}")
    nc = T // L

    def per_chunk(x):  # [B,T,...] -> [nc,B,L,...]
        return jnp.moveaxis(x.reshape(B, nc, L, *x.shape[2:]), 1, 0)

    xs = (per_chunk(xh), per_chunk(dt), per_chunk(log_decay),
          per_chunk(Bm), per_chunk(Cm))
    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(S, xs_c):
        xc, dtc, ldc, bc, cc = xs_c           # [B,L,H,P],[B,L,H],...,[B,L,N]
        lcum = jnp.cumsum(ldc, axis=1)        # [B,L,H] log prod a_1..a_t
        # inter-chunk: y_t += exp(lcum_t) * (S_0 . C_t)
        y0 = jnp.einsum("bhpn,bln->blhp", S, cc)
        y = y0 * jnp.exp(lcum)[..., None]
        # intra-chunk: G[t,j] = exp(lcum_t - lcum_j) dt_j (C_t.B_j), j<=t
        cb = jnp.einsum("bln,bjn->blj", cc, bc)            # [B,L,L]
        ratio = jnp.exp(jnp.clip(lcum[:, :, None] - lcum[:, None, :],
                                 None, 0.0))               # [B,L,L,H]
        g = cb[..., None] * ratio * dtc[:, None]           # [B,L(t),L(j),H]
        g = jnp.where(mask[None, :, :, None], g, 0.0)
        y = y + jnp.einsum("bljh,bjhp->blhp", g, xc)
        # carry: S_L = exp(lcum_L) S_0 + sum_j exp(lcum_L - lcum_j) dt_j x_j B_j
        wj = jnp.exp(lcum[:, -1:, :] - lcum) * dtc         # [B,L,H]
        S = S * jnp.exp(lcum[:, -1])[..., None, None] + jnp.einsum(
            "blhp,bln->bhpn", xc * wj[..., None], bc)
        return S, y

    S, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y, S


def layer_apply(cfg, p, x, cache: Optional[Dict]) -> Tuple[jnp.ndarray, Dict]:
    """x [B,T,D] -> (y [B,T,D], new_cache {"S","conv"})."""
    B, T, D = x.shape
    di, N, H, P = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_head_dim

    z = x @ p["Wz"]
    xi = x @ p["Wx"]
    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], conv_state)
    xi = jax.nn.silu(xi)

    dt = jax.nn.softplus((x @ p["Wdt"] + p["dt_bias"]).astype(F32))
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                   # [B,T,H]
    Bm = (x @ p["WB"]).astype(F32)
    Cm = (x @ p["WC"]).astype(F32)
    xh = xi.reshape(B, T, H, P).astype(F32)

    s0 = cache["S"] if cache is not None else jnp.zeros((B, H, P, N), F32)
    chunk = getattr(cfg, "ssm_chunk", 128)
    if T > 1 and T % min(chunk, T) == 0:
        y, S = _ssd_chunked(xh, dt, dt * A, Bm, Cm, s0.astype(F32), chunk)
    else:
        y, S = _ssd_scan(xh, dt, decay, Bm, Cm, s0.astype(F32))
    y = y + p["D_skip"].astype(F32)[None, None, :, None] * xh
    y = y.reshape(B, T, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["Wo"]
    new_cache = {"S": S, "conv": new_conv}
    return out, new_cache


def init_cache(cfg, batch: int, dtype=None) -> Dict:
    di, N, H, P = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_head_dim
    dt = jnp.dtype(dtype or cfg.dtype)
    return {"S": jnp.zeros((batch, H, P, N), F32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dt)}


def cache_specs() -> Dict:
    return {"S": ("batch", "heads", None, None),
            "conv": ("batch", None, "mlp_act")}


def param_count(cfg) -> int:
    d, di, n, h = cfg.d_model, d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    return (2 * d * di + 2 * d * n + d * h + 3 * h
            + cfg.ssm_conv * di + di + di * d)
