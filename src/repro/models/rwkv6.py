"""RWKV-6 "Finch" — attention-free LM with data-dependent decay.

HDP is inapplicable here (no QK^T score matrix exists — DESIGN.md
§Arch-applicability); the arch is implemented without it, as assigned.

Per layer: time-mix block (token shift, data-dependent decay w via LoRA,
WKV linear-attention recurrence with per-head state S[hd_k, hd_v], bonus u,
per-head group norm, gating) + channel-mix block (token shift, squared-ReLU
key, sigmoid receptance). Recurrence runs as lax.scan over time for train /
prefill and as a single state update for decode.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L

F32 = jnp.float32
LORA_R = 64


def _tm_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    h = d // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    names = ("r", "k", "v", "w", "g")
    p = {f"mu_{n}": jnp.full((d,), 0.5, dtype) for n in names}
    s = {f"mu_{n}": ("embed",) for n in names}
    for n in ("r", "k", "v", "g", "o"):
        p[f"W{n}"] = L.dense_init(L.key_for(rng, f"W{n}"), (d, d), dtype)
        s[f"W{n}"] = ("embed", "heads") if n != "o" else ("heads", "embed")
    p["w0"] = jnp.full((d,), -5.0, dtype)                 # decay bias
    p["wA"] = L.dense_init(L.key_for(rng, "wA"), (d, LORA_R), dtype)
    p["wB"] = L.dense_init(L.key_for(rng, "wB"), (LORA_R, d), dtype, scale=0.1)
    p["u"] = jnp.zeros((h, hd), dtype)                    # bonus
    p["gn_w"] = jnp.ones((h, hd), dtype)
    p["gn_b"] = jnp.zeros((h, hd), dtype)
    s.update(w0=("embed",), wA=("embed", None), wB=(None, "embed"),
             u=("heads", "head_dim"), gn_w=("heads", "head_dim"),
             gn_b=("heads", "head_dim"))
    return p, s


def _cm_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    d, f = cfg.d_model, cfg.d_ff
    p = {"mu_k": jnp.full((d,), 0.5, dtype),
         "mu_r": jnp.full((d,), 0.5, dtype),
         "Wk": L.dense_init(L.key_for(rng, "cWk"), (d, f), dtype),
         "Wv": L.dense_init(L.key_for(rng, "cWv"), (f, d), dtype),
         "Wr": L.dense_init(L.key_for(rng, "cWr"), (d, d), dtype)}
    s = {"mu_k": ("embed",), "mu_r": ("embed",), "Wk": ("embed", "mlp"),
         "Wv": ("mlp", "embed"), "Wr": ("embed", "embed")}
    return p, s


def _layer_init(cfg, rng, dtype):
    tm_p, tm_s = _tm_init(cfg, L.key_for(rng, "tm"), dtype)
    cm_p, cm_s = _cm_init(cfg, L.key_for(rng, "cm"), dtype)
    ln1_p, ln1_s = L.norm_init(cfg, dtype)
    ln2_p, ln2_s = L.norm_init(cfg, dtype)
    return ({"tm": tm_p, "cm": cm_p, "ln1": ln1_p, "ln2": ln2_p},
            {"tm": tm_s, "cm": cm_s, "ln1": ln1_s, "ln2": ln2_s})


def init_params(cfg, rng) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    emb_p, emb_s = L.embed_init(cfg, L.key_for(rng, "embed"), dtype)
    keys = jax.random.split(L.key_for(rng, "layers"), cfg.n_layers)
    layers_p = jax.vmap(lambda k: _layer_init(cfg, k, dtype)[0])(keys)
    _, layer_s = _layer_init(cfg, keys[0], dtype)
    layers_s = jax.tree.map(lambda ax: ("layers",) + tuple(ax), layer_s,
                            is_leaf=lambda x: isinstance(x, tuple))
    fin_p, fin_s = L.norm_init(cfg, dtype)
    return ({"embed": emb_p, "layers": layers_p, "final_norm": fin_p},
            {"embed": emb_s, "layers": layers_s, "final_norm": fin_s})


def _shift(x, x_prev):
    """Token shift: [B,S,D] -> previous token's features; x_prev [B,D]."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """WKV-6: r,k,v,w [B,T,H,hd]; state S [B,H,hd_k,hd_v].

    y_t = (S_t + (u*k_t) outer v_t)^T r_t;  S_{t+1} = diag(w_t) S_t + k_t (x) v_t
    """
    def step(S, xs):
        rt, kt, vt, wt = xs  # [B,H,hd]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), S  # [B,T,H,hd_v], final state


def _time_mix(cfg, p, x, x_prev, state):
    """Returns (out [B,S,D], new_x_prev [B,D], new_state [B,H,hd,hd])."""
    B, S, D = x.shape
    h, hd = D // cfg.ssm_head_dim, cfg.ssm_head_dim
    xs = _shift(x, x_prev)

    def mix(name):
        mu = p[f"mu_{name}"]
        return x * mu + xs * (1.0 - mu)

    r = (mix("r") @ p["Wr"]).reshape(B, S, h, hd)
    k = (mix("k") @ p["Wk"]).reshape(B, S, h, hd)
    v = (mix("v") @ p["Wv"]).reshape(B, S, h, hd)
    g = jax.nn.silu(mix("g") @ p["Wg"])
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x_w)))
    w_raw = p["w0"] + jnp.tanh(mix("w") @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(w_raw.astype(F32))).astype(x.dtype)
    w = w.reshape(B, S, h, hd)

    y, new_state = _wkv_scan(r.astype(F32), k.astype(F32), v.astype(F32),
                             w.astype(F32), p["u"].astype(F32),
                             state.astype(F32))
    y = L.group_norm_heads(y.astype(x.dtype), p["gn_w"], p["gn_b"])
    y = (y.reshape(B, S, D) * g) @ p["Wo"]
    return y, x[:, -1], new_state.astype(state.dtype)


def _channel_mix(cfg, p, x, x_prev):
    xs = _shift(x, x_prev)
    xk = x * p["mu_k"] + xs * (1.0 - p["mu_k"])
    xr = x * p["mu_r"] + xs * (1.0 - p["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    k = shd(k, "batch", None, "mlp_act")
    return jax.nn.sigmoid(xr @ p["Wr"]) * (k @ p["Wv"]), x[:, -1]


def _block(cfg, lp, x, cache):
    """cache per layer: {"state" [B,H,hd,hd], "tm_x" [B,D], "cm_x" [B,D]}."""
    h, hd = cfg.d_model // cfg.ssm_head_dim, cfg.ssm_head_dim
    B = x.shape[0]
    if cache is None:
        cache = {"state": jnp.zeros((B, h, hd, hd), F32),
                 "tm_x": jnp.zeros((B, cfg.d_model), x.dtype),
                 "cm_x": jnp.zeros((B, cfg.d_model), x.dtype)}
    hx = L.apply_norm(cfg, lp["ln1"], x)
    a, tm_x, state = _time_mix(cfg, lp["tm"], hx, cache["tm_x"], cache["state"])
    x = x + a
    hx = L.apply_norm(cfg, lp["ln2"], x)
    m, cm_x = _channel_mix(cfg, lp["cm"], hx, cache["cm_x"])
    x = x + m
    return x, {"state": state, "tm_x": tm_x, "cm_x": cm_x}


def _stack(cfg, params, x, cache):
    has_cache = cache is not None

    def body(carry, xs):
        lp = xs[0] if has_cache else xs
        lc = xs[1] if has_cache else None
        y, nc = _block(cfg, lp, carry, lc)
        return y, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], cache) if has_cache else params["layers"]
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, new_cache


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict:
    """RWKV cache is O(1) in sequence length — the long_500k enabler."""
    h, hd = cfg.d_model // cfg.ssm_head_dim, cfg.ssm_head_dim
    dt = jnp.dtype(dtype or cfg.dtype)
    return {
        "state": jnp.zeros((cfg.n_layers, batch, h, hd, hd), F32),
        "tm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
        "cm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
    }


def cache_specs(cfg) -> Dict:
    return {"state": ("layers", "batch", "heads", None, None),
            "tm_x": ("layers", "batch", "embed_act"),
            "cm_x": ("layers", "batch", "embed_act")}


def apply_train(cfg, params, batch, *, collect_stats: bool = False):
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg.d_model)
    x = shd(x, "batch", "seq_act", "embed_act")
    x, _ = _stack(cfg, params, x, None)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits_sharded(params["embed"], x)
    return logits, {"aux_loss": jnp.zeros((), F32), "hdp": None}


def apply_prefill(cfg, params, batch, cache, *, collect_stats: bool = False,
                  attn=None):
    del attn  # recurrent layers have no attention; accepted for uniformity
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg.d_model)
    x = shd(x, "batch", "seq_act", "embed_act")
    x, new_cache = _stack(cfg, params, x, cache)
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return L.lm_logits_sharded(params["embed"], x), new_cache, None


def apply_decode(cfg, params, token, cache, pos, *, collect_stats: bool = False,
                 attn=None):
    del attn  # recurrent layers have no attention; accepted for uniformity
    x = L.embed_tokens(params["embed"], token, cfg.d_model)
    x, new_cache = _stack(cfg, params, x, cache)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_logits(params["embed"], x), new_cache, None


def param_count(cfg) -> int:
    d, f = cfg.d_model, cfg.d_ff
    tm = 5 * d + 5 * d * d + d + d * LORA_R + LORA_R * d + 3 * d
    cm = 2 * d + d * f + f * d + d * d
    per_layer = tm + cm + 4 * d
    return cfg.n_layers * per_layer + cfg.vocab_size * d * 2 + d
