"""Decoder-only transformer LM (dense and MoE families).

Covers olmoe, llama4-scout, chameleon, nemotron-4, h2o-danube, qwen2,
granite: GQA, RoPE, qk-norm, QKV bias, SWA, squared-ReLU / GLU / GELU MLPs,
MoE with shared experts — all driven by ModelConfig. Layers are stacked and
scanned (small HLO, fast compiles, remat-able).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import attn_apply, attn_init

F32 = jnp.float32


def _layer_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    attn_p, attn_s = attn_init(cfg, L.key_for(rng, "attn"), dtype)
    ln1_p, ln1_s = L.norm_init(cfg, dtype)
    ln2_p, ln2_s = L.norm_init(cfg, dtype)
    if cfg.n_experts:
        ffn_p, ffn_s = M.moe_init(cfg, L.key_for(rng, "moe"), dtype)
    else:
        ffn_p, ffn_s = L.mlp_init(cfg, L.key_for(rng, "mlp"), dtype)
    return ({"attn": attn_p, "ln1": ln1_p, "ln2": ln2_p, "ffn": ffn_p},
            {"attn": attn_s, "ln1": ln1_s, "ln2": ln2_s, "ffn": ffn_s})


def init_params(cfg, rng) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    emb_p, emb_s = L.embed_init(cfg, L.key_for(rng, "embed"), dtype)
    keys = jax.random.split(L.key_for(rng, "layers"), cfg.n_layers)
    layers_p = jax.vmap(lambda k: _layer_init(cfg, k, dtype)[0])(keys)
    _, layer_s = _layer_init(cfg, keys[0], dtype)
    layers_s = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        layer_s, is_leaf=lambda x: isinstance(x, tuple))
    fin_p, fin_s = L.norm_init(cfg, dtype)
    return ({"embed": emb_p, "layers": layers_p, "final_norm": fin_p},
            {"embed": emb_s, "layers": layers_s, "final_norm": fin_s})


def _block(cfg, lp, x, *, mode, positions, cache, collect_stats,
           page_table=None, write_floor=None, attn=None, draft=None):
    h = L.apply_norm(cfg, lp["ln1"], x)
    a, new_cache, stats = attn_apply(
        cfg, lp["attn"], h, mode=mode, positions=positions, cache=cache,
        collect_stats=collect_stats, page_table=page_table,
        write_floor=write_floor, attn=attn, draft=draft)
    x = x + a
    h = L.apply_norm(cfg, lp["ln2"], x)
    if cfg.n_experts:
        m, aux = M.moe_apply(cfg, lp["ffn"], h)
    else:
        m, aux = L.mlp_apply(cfg, lp["ffn"], h), jnp.zeros((), F32)
    return x + m, new_cache, stats, aux


def _stack(cfg, params, x, *, mode, positions, cache, collect_stats,
           page_table=None, write_floor=None, attn=None, draft=None):
    """lax.scan over stacked layers; returns (x, new_cache, stats, aux).

    The KV cache rides in the scan CARRY with per-layer in-place
    dynamic-update-slice — emitting it as stacked scan outputs (`ys`)
    allocates a second full cache buffer that donation cannot alias
    (2-3 cache copies live at a 32k decode step)."""
    has_cache = cache is not None

    if not has_cache:
        def body(carry, lp):
            y, _, st, aux = _block(cfg, lp, carry, mode=mode,
                                   positions=positions, cache=None,
                                   collect_stats=collect_stats, attn=attn)
            return y, (st, aux)

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (stats, aux) = jax.lax.scan(body, x, params["layers"])
        return x, None, stats, aux

    def body(carry, lp):
        y, cache_all, li = carry
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
            cache_all)
        y, nc, st, aux = _block(cfg, lp, y, mode=mode, positions=positions,
                                cache=lc, collect_stats=collect_stats,
                                page_table=page_table,
                                write_floor=write_floor, attn=attn,
                                draft=draft)
        cache_all = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), li, 0),
            cache_all, nc)
        return (y, cache_all, li + 1), (st, aux)

    # no remat here: the cache path is inference-only (no backward), and
    # jax.checkpoint barriers force the carried cache to be COPIED twice
    # per layer (measured +160 ms memory_t at 32k decode)
    (x, new_cache, _), (stats, aux) = jax.lax.scan(
        body, (x, cache, jnp.asarray(0, jnp.int32)), params["layers"])
    return x, new_cache, stats, aux


def _embed_in(cfg, params, tokens):
    x = L.embed_tokens(params["embed"], tokens, cfg.d_model)
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos(tokens.shape[1], cfg.d_model).astype(x.dtype)
    return shd(x, "batch", "seq_act", "embed_act")


def apply_train(cfg, params, batch, *, collect_stats: bool = False):
    tokens = batch["tokens"]
    x = _embed_in(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, _, stats, aux = _stack(cfg, params, x, mode="train",
                              positions=positions, cache=None,
                              collect_stats=collect_stats)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits_sharded(params["embed"], x)
    return logits, {"aux_loss": aux.sum(), "hdp": stats}


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_specs(cfg) -> Dict:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def apply_prefill(cfg, params, batch, cache, *, collect_stats: bool = False,
                  pos_offset=0, attn=None):
    """Run the prompt; fills cache, returns last-position logits.

    pos_offset (scalar, may be traced): absolute position of tokens[:, 0] —
    nonzero for chunked prefill, where each chunk appends to the cache
    behind the previous ones."""
    tokens = batch["tokens"]
    x = _embed_in(cfg, params, tokens)
    positions = pos_offset + jnp.arange(tokens.shape[1])
    x, new_cache, stats, _ = _stack(cfg, params, x, mode="prefill",
                                    positions=positions, cache=cache,
                                    collect_stats=collect_stats, attn=attn)
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = L.lm_logits_sharded(params["embed"], x)
    return logits, new_cache, stats


def apply_decode(cfg, params, token, cache, pos, *, collect_stats: bool = False,
                 page_table=None, write_floor=None, attn=None, draft=None):
    """One decode step. token [B,1]; pos scalar int32 (aligned batch).

    page_table [B, nP] routes the step through the block-paged serving
    cache ({"k_pages","v_pages"[,"k_scout"]} leaves) instead of the dense
    contiguous layout. write_floor [B] fences each slot's shared
    read-only prefix pages from the K/V write (see attn_apply).

    token [B, S] with S > 1 is the speculative multi-query *verify*
    shape: ``pos`` must then be [B, S] consecutive positions per slot —
    all S rows are scored against the cache in one call, with per-row
    scout semantics identical to S sequential steps. draft: DraftProfile
    marking a self-speculative draft step (approximate attention)."""
    x = L.embed_tokens(params["embed"], token, cfg.d_model)
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos(token.shape[1], cfg.d_model,
                                 offset=pos).astype(x.dtype)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    x, new_cache, stats, _ = _stack(cfg, params, x, mode="decode",
                                    positions=positions, cache=cache,
                                    collect_stats=collect_stats,
                                    page_table=page_table,
                                    write_floor=write_floor, attn=attn,
                                    draft=draft)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(params["embed"], x)
    return logits, new_cache, stats


def param_count(cfg) -> int:
    d, f, v, hd = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.hd
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    if cfg.n_experts:
        ffn = cfg.n_experts * 3 * d * f + d * cfg.n_experts
        if cfg.n_shared_experts:
            ffn += 3 * d * f * cfg.n_shared_experts
    else:
        ffn = (3 if cfg.act == "silu_glu" else 2) * d * f
    per_layer = attn + ffn + 2 * d
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb + d


def active_param_count(cfg) -> int:
    if not cfg.n_experts:
        return param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    attn = d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd \
        + cfg.n_heads * cfg.hd * d
    ffn = cfg.n_experts_active * 3 * d * f + d * cfg.n_experts
    ffn += 3 * d * f * cfg.n_shared_experts
    per_layer = attn + ffn + 2 * d
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb + d
