"""Unified multi-head attention with HDP as a first-class feature.

Paths (selected by mode/config, all GQA-grouped, fp32 accumulation):

* ``chunked``   — flash-style lax.scan over KV chunks (train / prefill);
                  memory O(Sq * chunk) instead of O(Sq * Sk).
* ``local``     — block-local sliding-window attention, cost O(S * w).
* ``decode``    — single-query attention over a KV cache.
* ``hdp_*``     — the paper's pipeline, blockwise: integer scout pass ->
                  row-balanced block mask + early head gate -> approximate
                  (QK - FQ FK) attention on surviving blocks. Prefill scans
                  q-blocks twice (scout, attend); decode prunes KV pages.

Tensor conventions: activations x [B, S, D]; q [B, N, G, Sq, hd] where
N = kv heads, G = query group size (N*G = n_heads); k/v [B, Sk, N, hd].
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.attention import AttnCall, AttnSpec, attention
from repro.core import blocking
from repro.core.config import HDPConfig
from repro.core.hdp import calibrated_split, decode_scout
from repro.core.quant import (FRAC_SCOUT_SCALE, POISON_CODE, encode_pool,
                              encode_pool_scaled, pool_int_bits, pool_scale,
                              pool_view_finite, quantize_and_split,
                              quantize_fixed, roundtrip_pool,
                              scout_frac_codes, scout_int_codes)
from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L

_NEG = -1e30
F32 = jnp.float32


# ------------------------------------------------------------------ params
def attn_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    d, h, n, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": L.dense_init(L.key_for(rng, "wq"), (d, h, hd), dtype),
        "wk": L.dense_init(L.key_for(rng, "wk"), (d, n, hd), dtype),
        "wv": L.dense_init(L.key_for(rng, "wv"), (d, n, hd), dtype),
        "wo": L.dense_init(L.key_for(rng, "wo"), (h, hd, d), dtype, in_axis=-3),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((h, hd), dtype), bk=jnp.zeros((n, hd), dtype),
                 bv=jnp.zeros((n, hd), dtype))
        s.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                 bv=("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((hd,), dtype), k_norm=jnp.ones((hd,), dtype))
        s.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return p, s


# -------------------------------------------------------------- core maths
def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x, axis, target):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """[..., Sq, Sk] additive bias from position validity."""
    # include q validity so the mask always carries the full [Sq, Sk]
    # extent (cross-attention has neither causal nor window terms).
    valid = (k_pos[..., None, :] >= 0) & (q_pos[..., :, None] >= 0)
    if causal:
        valid &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window:
        valid &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return valid


def chunked_attention(q, k, v, *, q_pos, k_pos, chunk: int,
                      causal: bool = True, window: int = 0):
    """Flash-style scan over KV chunks. q [B,N,G,Sq,hd]; k,v [B,Sk,N,hd]."""
    B, N, G, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    nc = max(1, -(-Sk // chunk))
    Skp = nc * chunk
    k = _pad_axis(k, 1, Skp)
    v = _pad_axis(v, 1, Skp)
    k_pos = _pad_axis(k_pos + 1, 0, Skp) - 1  # pads become -1 (invalid)

    kc = k.reshape(B, nc, chunk, N, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, N, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(nc, chunk)

    m0 = jnp.full((B, N, G, Sq), _NEG, F32)
    l0 = jnp.zeros((B, N, G, Sq), F32)
    a0 = jnp.zeros((B, N, G, Sq, hd), F32)

    def body(carry, xs):
        m, l, acc = carry
        ki, vi, pi = xs
        s = jnp.einsum("bngqh,bcnh->bngqc", q, ki,
                       preferred_element_type=F32) * scale
        valid = _mask_bias(q_pos, pi, causal, window)
        s = jnp.where(valid, s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        pv = jnp.einsum("bngqc,bcnh->bngqh", p.astype(v.dtype), vi,
                        preferred_element_type=F32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def local_attention(q, k, v, *, q_pos, k_pos, window: int, causal: bool = True):
    """Block-local sliding window: each q block attends self+prev block.

    Requires block size == window; cost O(S * 2w * hd)."""
    B, N, G, Sq, hd = q.shape
    Sk = k.shape[1]
    c = window
    Sqp, Skp = _ceil_to(Sq, c), _ceil_to(Sk, c)
    assert Sqp == Skp, "local attention expects aligned q/k (self-attn)"
    qb = _pad_axis(q, 3, Sqp).reshape(B, N, G, Sqp // c, c, hd)
    kb = _pad_axis(k, 1, Skp).reshape(B, Skp // c, c, N, hd)
    vb = _pad_axis(v, 1, Skp).reshape(B, Skp // c, c, N, hd)
    qp = _pad_axis(q_pos + 1, 0, Sqp).reshape(Sqp // c, c) - 1
    kp = _pad_axis(k_pos + 1, 0, Skp).reshape(Skp // c, c) - 1

    def pair(x):  # concat previous block: [B, nb, 2c, N, hd]
        prev = jnp.roll(x, 1, axis=1).at[:, 0].set(0.0)
        return jnp.concatenate([prev, x], axis=2)

    k2, v2 = pair(kb), pair(vb)
    kp2 = jnp.concatenate([jnp.roll(kp, 1, 0).at[0].set(-1), kp], axis=1)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bngtqh,btcnh->bngtqc", qb, k2,
                   preferred_element_type=F32) * scale
    valid = _mask_bias(qp, kp2, causal, window)  # [nb, c, 2c]
    s = jnp.where(valid, s, _NEG)
    mx = s.max(-1, keepdims=True)
    p = jnp.exp(s - mx)
    p = jnp.where(valid, p, 0.0)
    den = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bngtqc,btcnh->bngtqh", (p / den).astype(v.dtype), v2,
                     preferred_element_type=F32)
    out = out.reshape(B, N, G, Sqp, hd)[:, :, :, :Sq]
    return out.astype(q.dtype)


def decode_attention(q, k, v, *, q_pos, k_pos, window: int = 0,
                     causal: bool = True):
    """Single (or few) query tokens vs cache. q [B,N,G,Sq,hd], k/v [B,Sk,N,hd]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bngqh,bsnh->bngqs", q, k, preferred_element_type=F32) * scale
    valid = _mask_bias(q_pos, k_pos, causal, window)
    s = jnp.where(valid, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    out = jnp.einsum("bngqs,bsnh->bngqh", p.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


# ----------------------------------------------------------------- HDP path
def hdp_prefill_attention(q, k, v, *, q_pos, k_pos, hdp: HDPConfig,
                          window: int = 0, return_stats: bool = False):
    """Two-pass blockwise HDP (Alg. 2 adapted to TPU-sized tiles).

    Pass A: integer scout per q-block -> theta, row threshold, keep mask,
    head importance. Pass B: approximate attention on surviving blocks.
    """
    B, N, G, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = hdp.block_q, hdp.block_k
    Sqp, Skp = _ceil_to(Sq, bq), _ceil_to(Sk, bk)
    nq, nk = Sqp // bq, Skp // bk
    scale = 1.0 / (hd ** 0.5)

    sq, qq, iq, fq = calibrated_split(_pad_axis(q, 3, Sqp).astype(F32), hdp)
    sk, kq, ik, fk = calibrated_split(_pad_axis(k, 1, Skp).astype(F32), hdp)
    score_rescale = 1.0 / (sq * sk)
    vp = _pad_axis(v, 1, Skp)
    qp = _pad_axis(q_pos + 1, 0, Sqp) - 1
    kp = _pad_axis(k_pos + 1, 0, Skp) - 1

    def per_qblock(x):  # [B,N,G,Sqp,...] -> [nq, B,N,G,bq,...]
        xs = x.reshape(B, N, G, nq, bq, *x.shape[4:])
        return jnp.moveaxis(xs, 3, 0)

    iq_b, qq_b, fq_b = per_qblock(iq), per_qblock(qq), per_qblock(fq)
    qp_b = qp.reshape(nq, bq)

    # ---- Pass A: integer scout -> keep mask, head importance ----
    def scout(carry, xs):
        th_acc, n_acc, nb_acc = carry
        iq_i, qp_i = xs
        s_int = jnp.einsum("bngqh,bsnh->bngqs", iq_i, ik,
                           preferred_element_type=F32)
        valid = _mask_bias(qp_i, kp, hdp.causal, window)
        theta, bvalid = blocking.pooled_block_theta(s_int, valid, bk)
        if hdp.block_pruning:
            thr = blocking.row_threshold(theta, hdp.rho_b, bvalid)
            keep = blocking.block_keep_mask(theta, thr, bvalid)
        else:
            keep = jnp.broadcast_to(bvalid, theta.shape)
        th_acc = th_acc + jnp.where(bvalid, theta, 0.0).sum(-1)
        n_acc = n_acc + valid.sum().astype(F32)
        nb_acc = nb_acc + bvalid.sum().astype(F32)
        return (th_acc, n_acc, nb_acc), keep

    (theta_head, n_valid, n_blocks), keep_rows = jax.lax.scan(
        scout, (jnp.zeros((B, N, G), F32), jnp.zeros((), F32),
                jnp.zeros((), F32)), (iq_b, qp_b))
    if hdp.normalize_head_score:
        theta_head = theta_head / jnp.maximum(n_valid, 1.0)
    head_kept = (theta_head > hdp.tau_h) if hdp.head_pruning \
        else jnp.ones_like(theta_head, bool)

    # ---- Pass B: approximate attention on surviving blocks ----
    def attend(_, xs):
        qq_i, fq_i, qp_i, keep_i = xs
        s = jnp.einsum("bngqh,bsnh->bngqs", qq_i, kq,
                       preferred_element_type=F32)
        if hdp.approx:
            s = s - jnp.einsum("bngqh,bsnh->bngqs", fq_i, fk,
                               preferred_element_type=F32)
        s = s * (scale * score_rescale)
        valid = _mask_bias(qp_i, kp, hdp.causal, window)
        keep_e = jnp.repeat(keep_i, bk, axis=-1)[..., None, :] & valid
        s = jnp.where(keep_e, s, _NEG)
        softmax = blocking.approx_softmax if hdp.approx_softmax else None
        if softmax is not None:
            p = softmax(s, keep_e)
        else:
            mx = s.max(-1, keepdims=True)
            p = jnp.exp(s - mx)
            p = jnp.where(keep_e, p, 0.0)
            p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
        o = jnp.einsum("bngqs,bsnh->bngqh", p.astype(vp.dtype), vp,
                       preferred_element_type=F32)
        return (), o

    _, outs = jax.lax.scan(attend, (), (qq_b, fq_b, qp_b, keep_rows))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, N, G, Sqp, hd)[:, :, :, :Sq]
    out = out * head_kept[..., None, None].astype(out.dtype)

    stats = None
    if return_stats:
        kept = keep_rows.astype(F32).sum() / (B * N * G)
        stats = {
            "block_sparsity": 1.0 - kept / jnp.maximum(n_blocks, 1.0),
            "head_sparsity": 1.0 - head_kept.astype(F32).mean(),
            "theta_head": theta_head,
        }
    return out.astype(q.dtype), stats


def _expand_keep(keep, block_k, valid, ndim):
    """[..., nk] or [..., Sq, nk] block keep -> element mask of `ndim` dims.

    Pooled (decode) masks lack the query axis and broadcast over it;
    per-query (verify) masks already carry Sq and expand in place."""
    keep_e = jnp.repeat(keep, block_k, axis=-1)
    if keep_e.ndim < ndim:
        keep_e = keep_e[..., None, :]
    return keep_e & valid


def _head_gate(out, head_kept):
    """Early head gate: pooled [...] or per-query [..., Sq] gates both
    broadcast against [..., Sq, hd] by appending trailing axes."""
    gate = head_kept
    while gate.ndim < out.ndim:
        gate = gate[..., None]
    return out * gate.astype(out.dtype)


def _approx_block_attention(qq, fq, kq, fk, v, keep, valid, head_kept, *,
                            block_k, scale, approx, scores=None):
    """Shared decode stage: approximate scores (QK^T - FQ FK^T) on blocks
    surviving `keep`, exclusion softmax, early head gate.

    `scale` folds 1/sqrt(hd) and any calibration rescale; `block_k` is the
    width the [..., nk] keep mask expands by to match the score columns.
    `scores` (pre-scale) overrides the QK^T - FQ FK^T computation — the
    self-speculative draft hands its integer/scout scores in here."""
    if scores is None:
        s = jnp.einsum("bngqh,bsnh->bngqs", qq, kq,
                       preferred_element_type=F32)
        if approx:
            s = s - jnp.einsum("bngqh,bsnh->bngqs", fq, fk,
                               preferred_element_type=F32)
    else:
        s = scores
    s = s * scale
    keep_e = _expand_keep(keep, block_k, valid, s.ndim)
    s = jnp.where(keep_e, s, _NEG)
    mx = s.max(-1, keepdims=True)
    p = jnp.exp(s - mx)
    p = jnp.where(keep_e, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bngqs,bsnh->bngqh", p.astype(v.dtype), v,
                     preferred_element_type=F32)
    return _head_gate(out, head_kept)


def _block_sparsity_stats(keep, bvalid, head_kept):
    """Per-slot pruned fractions over *valid* blocks — decode-mode stats
    leaves carry the batch dim ([B]) so the serving engine can mask
    parked slots out of the batchwise means (prefill stats stay scalar:
    exact-size stacking means every row is real)."""
    ax = tuple(range(1, keep.ndim))
    kept = (keep & bvalid).astype(F32).sum(ax)
    tot = jnp.maximum(
        jnp.broadcast_to(bvalid, keep.shape).astype(F32).sum(ax), 1.0)
    hax = tuple(range(1, head_kept.ndim))
    return {"block_sparsity": 1.0 - kept / tot,
            "head_sparsity": 1.0 - head_kept.astype(F32).mean(hax)}


def hdp_decode_attention(q, k, v, *, q_pos, k_pos, hdp: HDPConfig,
                         window: int = 0, return_stats: bool = False,
                         draft=None, per_query: bool = False):
    """KV-page pruning for decode (TPU adaptation, DESIGN.md §2).

    The integer scout reads K (int8-representable) once; pruned pages'
    V (and full-precision K) never need fetching — the memory-roofline win.

    ``draft`` (a DraftProfile, thresholds already overlaid into ``hdp``)
    switches the score source to the draft approximation; ``per_query``
    runs the scout per query row (the multi-query verify shape).
    """
    B, N, G, Sq, hd = q.shape
    Sk = k.shape[1]
    bk = hdp.block_k
    Skp = _ceil_to(Sk, bk)
    scale = 1.0 / (hd ** 0.5)

    sq, qq, iq, fq = calibrated_split(q.astype(F32), hdp)
    sk, kq, ik, fk = calibrated_split(_pad_axis(k, 1, Skp).astype(F32), hdp)
    score_rescale = 1.0 / (sq * sk)
    vp = _pad_axis(v, 1, Skp)
    kp = _pad_axis(k_pos + 1, -1 if k_pos.ndim > 1 else 0, Skp) - 1

    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik, preferred_element_type=F32)
    valid = _mask_bias(q_pos, kp, hdp.causal, window)
    # the (small) query group is pooled into one block row per head —
    # unless per_query, where each verify row scouts for itself
    keep, bvalid, theta, theta_head, head_kept = decode_scout(
        s_int, valid, hdp, per_query=per_query)

    scores = None
    if draft is not None and draft.scores != "approx":
        # draft scores from the scout copies: s_int alone ("int") or
        # QQ·IK + IQ·FK^ ("scout"). The dense layout recomputes the
        # copies per step (its cache holds full-precision K), but the
        # *score* semantics — including FK's 2^-6 re-quantization — match
        # the paged scout-pool draft bit for bit.
        scores = s_int
        if draft.scores == "scout":
            fkh = jnp.round(fk * FRAC_SCOUT_SCALE) / FRAC_SCOUT_SCALE
            scores = scores \
                + jnp.einsum("bngqh,bsnh->bngqs", fq, ik,
                             preferred_element_type=F32) \
                + jnp.einsum("bngqh,bsnh->bngqs", iq, fkh,
                             preferred_element_type=F32)

    out = _approx_block_attention(qq, fq, kq, fk, vp, keep, valid, head_kept,
                                  block_k=bk, scale=scale * score_rescale,
                                  approx=hdp.approx, scores=scores)

    stats = None
    if return_stats:
        stats = {**_block_sparsity_stats(keep, bvalid, head_kept),
                 "theta_head": theta_head}
    return out.astype(q.dtype), stats


def _fixed_split(x, hdp: HDPConfig):
    """Calibration-free fixed-point split (xq, I, F).

    The paged serving cache stores the scout copy of K at *write* time, so
    the grid must be static (the paper's co-processor model: the host hands
    over pre-quantized fixed-point tensors). Elementwise by construction —
    values in pruned pages can never leak into kept positions through a
    data-dependent scale.
    """
    return quantize_and_split(x.astype(F32), hdp.int_bits, hdp.frac_bits)


def scout_int8(k, hdp: HDPConfig):
    """Write-time int8 scout copy of K (what FUM always streams).

    Thin config-aware wrapper over the shared ``core.quant`` pool-quant
    module — the SAME codes a quantized pool derives as its stage-1
    view, so fp32 and int8 pools scout on identical grids."""
    return scout_int_codes(k, hdp.int_bits, hdp.frac_bits)


def scout_frac_int8(k, hdp: HDPConfig):
    """Write-time int8 quantized-fraction scout copy of K.

    The self-speculative draft reconstructs near-exact approximate scores
    from the two int8 copies alone (``QQ·IK + IQ·FK^``), so a draft step
    never reads the full-precision K pool; stored only when a fp32-pool
    engine speculates (quantized pools derive the fraction view from
    their codes instead)."""
    return scout_frac_codes(k, hdp.int_bits, hdp.frac_bits)


def _dequant_pages(pages, scale):
    """Gathered pool pages [..., ps, N, hd] + per-page scales [..., N]
    -> fp32 values; the POISON_CODE sentinel (int8 pools) and a NaN page
    scale both surface as NaN (the stage-3 poison tripwires)."""
    if pages.dtype == jnp.int8:
        vals = jnp.where(pages == POISON_CODE, jnp.nan, pages.astype(F32))
    else:  # fp8 V: the exponent does the scale's job (scale stays 1.0)
        vals = pages.astype(F32)
    return vals * scale[..., None, :, None].astype(F32)


def resolve_write_pages(positions, page_table, page_size, write_floor=None):
    """[B, S] write positions -> [B, S] destination pool page per write.

    THE single implementation of the write-side position->page
    resolution and its safety fences — the decode K/V scatter and the
    speculative rollback poison must agree on it exactly:

    * columns past the table width redirect to the scratch page
      (speculative staging can run past the allocation near max_len);
    * columns below the slot's ``write_floor`` redirect to the scratch
      page (shared read-only prefix pages are immutable);
    * unallocated columns are already 0 (scratch) in the table.
    """
    nP = page_table.shape[1]
    pcol = positions // page_size
    pidx = jnp.take_along_axis(page_table, jnp.minimum(pcol, nP - 1), axis=1)
    pidx = jnp.where(pcol < nP, pidx, 0)
    if write_floor is not None:
        pidx = jnp.where(pcol >= write_floor[:, None], pidx, 0)
    return pidx


def _paged_scan_attention(qq, fq, k_pool, v_pool, gather_idx, keep, valid,
                          head_kept, *, hdp: HDPConfig, ps: int, cpp: int,
                          scale: float, k_scale=None, v_scale=None):
    """Stage 2+3 as an online-softmax scan over page chunks.

    Peak stage-2 memory is O(B * cpp * ps) — one chunk of gathered pages —
    instead of the O(B * Sk) dense materialization; pruned pages stay
    scratch-redirected, so their full-precision memory is never read.
    Quantized pools dequantize per chunk (``k_scale``/``v_scale`` are the
    per-page scale arrays), so dequantized tiles never round-trip HBM.
    Reduction order differs from the one-shot dense softmax by page-chunk
    grouping (ULP-level output differences across the chunk boundary).
    """
    B, N, G, Sq, hd = qq.shape
    nP = gather_idx.shape[1]
    nc = -(-nP // cpp)
    pad = nc * cpp - nP
    Sk = nP * ps
    idx_p = jnp.pad(gather_idx, ((0, 0), (0, pad)))       # pads -> scratch
    keep_p = jnp.pad(keep, ((0, 0),) * (keep.ndim - 1) + ((0, pad),))
    valid_f = jnp.broadcast_to(valid, (B, 1, 1, Sq, Sk))
    valid_p = jnp.pad(valid_f, ((0, 0),) * 4 + ((0, pad * ps),))

    idx_c = jnp.moveaxis(idx_p.reshape(B, nc, cpp), 1, 0)
    # keep is [B,N,G,nP] (pooled) or [B,N,G,Sq,nP] (per-query verify)
    keep_c = jnp.moveaxis(keep_p.reshape(*keep.shape[:-1], nc, cpp), -2, 0)
    valid_c = jnp.moveaxis(
        valid_p.reshape(B, 1, 1, Sq, nc, cpp * ps), 4, 0)

    m0 = jnp.full((B, N, G, Sq), _NEG, F32)
    l0 = jnp.zeros((B, N, G, Sq), F32)
    a0 = jnp.zeros((B, N, G, Sq, hd), F32)

    def body(carry, xs):
        m, l, acc = carry
        idx_i, keep_i, valid_i = xs
        if k_scale is not None:
            k_i = _dequant_pages(k_pool[idx_i], k_scale[idx_i])
            v_i = _dequant_pages(v_pool[idx_i], v_scale[idx_i])
            k_i = k_i.reshape(B, cpp * ps, N, hd)
            v_i = v_i.reshape(B, cpp * ps, N, hd)
        else:
            k_i = k_pool[idx_i].reshape(B, cpp * ps, N, hd)
            v_i = v_pool[idx_i].reshape(B, cpp * ps, N, hd)
        kq_i, _, fk_i = _fixed_split(k_i, hdp)
        s = jnp.einsum("bngqh,bsnh->bngqs", qq, kq_i,
                       preferred_element_type=F32)
        if hdp.approx:
            s = s - jnp.einsum("bngqh,bsnh->bngqs", fq, fk_i,
                               preferred_element_type=F32)
        s = s * scale
        keep_e = _expand_keep(keep_i, ps, valid_i, s.ndim)
        s = jnp.where(keep_e, s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(keep_e, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        pv = jnp.einsum("bngqs,bsnh->bngqh", p.astype(v_i.dtype), v_i,
                        preferred_element_type=F32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    (_, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (idx_c, keep_c, valid_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return _head_gate(out, head_kept)


def _paged_fum_kernel_stage3(qq, k_pool, v_pool, table, keep, head_kept,
                             q_pos, fetched, *, hdp: HDPConfig, ps: int,
                             k_scale=None, v_scale=None):
    """Stage 2+3 through the gather-free Pallas kernel.

    Compresses the OR-over-heads (and, for multi-query verify, OR-over-
    query-rows) page fetch list to (pool page ids, logical slot
    positions, counts) — the scalar-prefetch arrays whose values drive
    the kernel's K/V BlockSpec index maps, so surviving pages stream
    straight from the pool and pruned pages are never DMA'd (no gathered
    intermediate at all). A verify call streams each surviving page once
    for ALL Sq query rows — the pool is read once per round.
    """
    from repro.kernels.hdp_paged_decode import hdp_paged_fum_decode
    from repro.kernels.ops import _auto_interpret

    B, N, G, Sq, hd = qq.shape
    nP = table.shape[1]
    # normalize to the per-query-row shapes the kernel consumes (pooled
    # decode masks broadcast over the single query row)
    keep_q = keep if keep.ndim == 5 else keep[..., None, :]
    keep_q = jnp.broadcast_to(keep_q, (B, N, G, Sq, nP))
    # kept pages in ascending logical order (monotone pool DMA), padded
    # with the scratch page past each row's count
    big = jnp.iinfo(jnp.int32).max
    key = jnp.where(fetched, jnp.arange(nP, dtype=jnp.int32)[None], big)
    logical = jnp.sort(key, axis=-1)
    counts = fetched.sum(-1).astype(jnp.int32)
    in_range = jnp.arange(nP)[None] < counts[:, None]
    logical = jnp.where(in_range, logical, 0)
    page_ids = jnp.where(in_range,
                         jnp.take_along_axis(table, logical, axis=1), 0)
    keep_sel = jnp.take_along_axis(
        keep_q, logical[:, None, None, None, :], axis=-1)
    keep_in = keep_sel.transpose(0, 4, 1, 2, 3).astype(jnp.int32)
    # row 0's extent; the kernel adds the query index (consecutive rows)
    kv_len = (q_pos.reshape(B, Sq)[:, 0] + 1).astype(jnp.int32)
    out = hdp_paged_fum_decode(
        qq, k_pool, v_pool, page_ids, logical, counts,
        keep_in, kv_len, approx=hdp.approx, int_bits=hdp.int_bits,
        frac_bits=hdp.frac_bits, k_scale=k_scale, v_scale=v_scale,
        interpret=_auto_interpret(None))
    return _head_gate(out, head_kept)


def hdp_paged_decode_attention(q, k_pool, v_pool, ik_pool, table, *,
                               q_pos, k_pos, hdp: HDPConfig, window: int = 0,
                               return_stats: bool = False,
                               stage3: str = "xla", page_chunk: int = 128,
                               draft=None, per_query: bool = False,
                               fk_pool=None, k_scale=None, v_scale=None,
                               kv_scale: str = "grid"):
    """HDP decode over a block-paged KV cache — the FUM dataflow in XLA.

    q [B,N,G,Sq,hd]; k/v_pool [P,ps,N,hd] page pools (page 0 is the
    reserved scratch page); ik_pool [P,ps,N,hd] int8 scout copy of K;
    table [B,nP] int32 page table (0-padded).

    An int8 ``k_pool`` switches on the quantized-pool path:
    ``k_scale``/``v_scale`` [P, N] carry the per-page scales, ``ik_pool``
    and ``fk_pool`` are ignored — the integer and fraction scout copies
    are *derived as views of the codes* (finite even for poisoned
    pages/positions, like the separate fp32-pool copies they replace) —
    and every stage-3 consumer dequantizes in place of its gather, so
    pruned pages still never DMA. Decoded values land exactly on the
    fixed-point grid stage 3 snaps K to, so the downstream maths is
    shared verbatim with the fp32 path.

    Stage 1 streams the int8 scout copy for EVERY allocated page (the
    paper's always-read integer pass), pools it into per-page importances
    and derives the keep mask + early head gate (core.hdp.decode_scout).
    Stage 2 fetches full-precision K/V only for surviving pages — pruned
    pages' gather indices are redirected to the scratch page, so their
    memory is never touched (the TPU kernel analogue never DMAs them).
    Stage 3 runs the approximate attention QK^T - FQ FK^T on the fetched
    pages with the keep mask excluded from the softmax.

    ``stage3`` selects the 2+3 implementation (backend selection lives in
    ``repro.attention``; this function is the shared stage pipeline):

    * ``"xla"`` — contexts up to ``page_chunk`` columns gather kept pages
      into one contiguous slab (exactly the dense reduction order);
      longer contexts run an online-softmax scan over page chunks, so
      stage-2 memory stays O(page_chunk) instead of O(Sk).
    * ``"pallas_paged"`` — the gather-free FUM kernel: scalar-prefetched
      page ids index the pool directly (interpret mode off-TPU).
    * ``"pallas_block"`` — the block-sparse kernel on a densified gather
      (the pre-kernel route, kept for the conformance matrix).

    ``kv_scale="absmax"`` (quantized pools only) reads per-page
    *calibrated* scales instead of assuming the static power-of-two
    grid: stage 1 dequantizes the scout stream through a sanitized copy
    of ``k_scale`` (NaN freed-page poison -> the static step, poison
    codes -> 0, so the scout stays finite exactly as on the static
    grid), and the stage-3 consumers already dequantize through the
    gathered scales. The FUM kernel derives its scout from the static
    grid, so ``stage3="pallas_paged"`` falls back to "xla" here.

    ``per_query`` runs the scout per query row (the multi-query verify
    shape: each of the Sq rows computes the keep mask / head gate its own
    single-token step would); ``draft`` (a DraftProfile — thresholds
    already overlaid into ``hdp``) switches stage 3 to the draft score
    source, under which the full-precision K pool is NEVER read: the
    scores come from the int8 scout copy stage 1 streams anyway, and only
    surviving pages' V is fetched.
    """
    B, N, G, Sq, hd = q.shape
    P, ps, _, _ = k_pool.shape
    nP = table.shape[1]
    Sk = nP * ps
    scale = 1.0 / (hd ** 0.5)
    quantized = k_pool.dtype == jnp.int8
    absmax = quantized and kv_scale == "absmax"

    # ---- stage 1: integer scout on the always-streamed int8 copy ----
    if absmax:
        # calibrated scales: dequantize the scout stream through a
        # sanitized scale copy — poison codes -> 0 and NaN freed-page
        # scales -> the static step, preserving the scout-always-finite
        # contract of the static-grid view
        codes = k_pool[table]                            # [B,nP,ps,N,hd]
        ksc = k_scale[table]                             # [B,nP,N]
        ksc = jnp.where(jnp.isfinite(ksc), ksc, pool_scale(hdp.int_bits))
        cf = jnp.where(codes == POISON_CODE, 0, codes).astype(F32)
        k_fin = (cf * ksc[:, :, None, :, None]).reshape(B, Sk, N, hd)
        ik = jnp.trunc(k_fin)
    elif quantized:
        # the pool's codes ARE the scout stream: the finite static-grid
        # view (poison sentinels -> 0, masked anyway) truncates to the
        # same integer parts the fp32 pools' write-time copy stored
        k_fin = pool_view_finite(k_pool[table], hdp.int_bits)
        k_fin = k_fin.reshape(B, Sk, N, hd)
        ik = jnp.trunc(k_fin)
    else:
        ik = ik_pool[table].reshape(B, Sk, N, hd).astype(F32)
    qq, iq, fq = _fixed_split(q, hdp)
    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik, preferred_element_type=F32)
    valid = _mask_bias(q_pos, k_pos, hdp.causal, window)
    keep, bvalid, theta, theta_head, head_kept = decode_scout(
        s_int, valid, hdp, per_query=per_query)

    # ---- stage 2: fetch-upon-mask page selection ----
    # page fetch granularity is OR-over-heads (a page holds all kv heads)
    # and, under multi-query verify, OR-over-query-rows (the pool is read
    # once per round); the per-head/per-row keep mask still applies inside
    # the softmax below. Early head-gated heads (output zeroed) don't
    # demand their pages at all.
    fetched = (keep & head_kept[..., None]).any(
        axis=tuple(range(1, keep.ndim - 1)))                  # [B, nP]

    if stage3 != "xla" and window:
        # the kernels' per-row validity is an upper bound (cols < kv_len)
        # and cannot express the sliding-window lower bound; fall back to
        # the jnp path rather than silently attending out-of-window keys
        stage3 = "xla"
    if stage3 == "pallas_block" and per_query:
        # the densifying block kernel's reshapes are Sq-unaware; fall
        # back like the windowed case instead of crashing a direct
        # conformance call (registry dispatch never routes verify here)
        stage3 = "xla"
    if stage3 == "pallas_paged" and absmax:
        # the FUM kernel derives its in-register scout from the STATIC
        # grid; under calibrated scales that scout would disagree with
        # the one above — fall back rather than fork the keep mask
        stage3 = "xla"
    if draft is not None and draft.scores != "approx":
        # draft stage 3: scores from the int8 scout copies — s_int alone
        # ("int") or QQ·IK + IQ·FK^ ("scout": the quantized-fraction copy
        # recovers the exact pass's scores to within its 2^-6 grid);
        # k_pool is never touched, and V is gathered only for surviving
        # pages (scratch-redirect)
        s = s_int
        if draft.scores == "scout":
            if quantized:
                # the fraction view comes straight off the codes (exact:
                # the coarse pool grid is a subset of the 2^-6 scout
                # grid), so no separate fraction pool exists to read
                fkh = k_fin - ik
            elif fk_pool is None:
                # the IQ·FK^ term cannot be derived without reading the
                # full-precision pool — which is exactly what this score
                # mode promises never to do; surface the misuse instead
                # of silently serving lower-fidelity drafts
                raise ValueError(
                    'draft scores="scout" needs the f_scout pool '
                    "(PagedKVCache(draft_scout=True)); pass fk_pool or "
                    'use scores="int"')
            else:
                fkh = fk_pool[table].reshape(B, Sk, N, hd).astype(F32) \
                    / FRAC_SCOUT_SCALE
            s = s + jnp.einsum("bngqh,bsnh->bngqs", fq, ik,
                               preferred_element_type=F32) \
                  + jnp.einsum("bngqh,bsnh->bngqs", iq, fkh,
                               preferred_element_type=F32)
        gather_idx = jnp.where(fetched, table, 0)         # pruned -> scratch
        if quantized:
            v = _dequant_pages(v_pool[gather_idx], v_scale[gather_idx])
            v = v.reshape(B, Sk, N, hd)
        else:
            v = v_pool[gather_idx].reshape(B, Sk, N, hd)
        out = _approx_block_attention(None, None, None, None, v, keep, valid,
                                      head_kept, block_k=ps, scale=scale,
                                      approx=False, scores=s)
    elif stage3 == "pallas_paged":
        out = _paged_fum_kernel_stage3(qq, k_pool, v_pool, table, keep,
                                       head_kept, q_pos, fetched,
                                       hdp=hdp, ps=ps,
                                       k_scale=k_scale if quantized else None,
                                       v_scale=v_scale if quantized else None)
    elif stage3 == "pallas_block":
        from repro.kernels.hdp_block_attn import hdp_block_sparse_attention
        from repro.kernels.ops import _auto_interpret
        from repro.kernels.ref import keep_mask_to_indices

        gather_idx = jnp.where(fetched, table, 0)         # pruned -> scratch
        if quantized:
            k = _dequant_pages(k_pool[gather_idx], k_scale[gather_idx])
            v = _dequant_pages(v_pool[gather_idx], v_scale[gather_idx])
            k = k.reshape(B, Sk, N, hd)
            v = v.reshape(B, Sk, N, hd)
        else:
            k = k_pool[gather_idx].reshape(B, Sk, N, hd)
            v = v_pool[gather_idx].reshape(B, Sk, N, hd)
        H = N * G
        def per_head(x):  # [B,Sk,N,hd] -> [B,H,Sk,hd]
            xh = jnp.repeat(x.transpose(0, 2, 1, 3), G, axis=1)
            return xh
        kq_h = per_head(quantize_fixed(k.astype(F32), hdp.int_bits,
                                       hdp.frac_bits))
        v_h = per_head(v)
        qq_h = qq.reshape(B, H, Sq, hd)
        keep_h = keep.reshape(B, H, 1, nP)
        kv_idx, counts = keep_mask_to_indices(
            keep_h, theta.reshape(B, H, 1, nP), nP)
        # per-row validity: cols <= current position (replaces the kernel's
        # aligned-self-attention causal mask, wrong for cached decode)
        lens = (q_pos.reshape(B)[:, None] + 1) * jnp.ones((B, H), jnp.int32)
        out = hdp_block_sparse_attention(
            qq_h, kq_h, v_h, kv_idx, counts, head_kept.reshape(B, H),
            causal=False, approx=hdp.approx, block_q=max(8, Sq),
            block_k=ps, score_scale=1.0, kv_len=lens,
            interpret=_auto_interpret(None))
        out = out.reshape(B, N, G, Sq, hd)
    else:
        gather_idx = jnp.where(fetched, table, 0)         # pruned -> scratch
        cpp = max(1, page_chunk // ps)                    # pages per chunk
        if nP <= cpp:
            # one chunk covers the context: gather kept pages into a slab
            # and reduce exactly like the dense-layout decode (keeps paged
            # and dense engines token-identical on short contexts)
            if quantized:
                k = _dequant_pages(k_pool[gather_idx], k_scale[gather_idx])
                v = _dequant_pages(v_pool[gather_idx], v_scale[gather_idx])
                k = k.reshape(B, Sk, N, hd)
                v = v.reshape(B, Sk, N, hd)
            else:
                k = k_pool[gather_idx].reshape(B, Sk, N, hd)
                v = v_pool[gather_idx].reshape(B, Sk, N, hd)
            kq, _, fk = _fixed_split(k, hdp)
            out = _approx_block_attention(qq, fq, kq, fk, v, keep, valid,
                                          head_kept, block_k=ps, scale=scale,
                                          approx=hdp.approx)
        else:
            out = _paged_scan_attention(qq, fq, k_pool, v_pool, gather_idx,
                                        keep, valid, head_kept, hdp=hdp,
                                        ps=ps, cpp=cpp, scale=scale,
                                        k_scale=k_scale if quantized else None,
                                        v_scale=v_scale if quantized else None)

    stats = None
    if return_stats:
        alloc = jnp.maximum((table > 0).astype(F32).sum(-1), 1.0)   # [B]
        stats = {**_block_sparsity_stats(keep, bvalid, head_kept),
                 "page_sparsity": 1.0 - jnp.minimum(
                     (fetched & (table > 0)).astype(F32).sum(-1) / alloc, 1.0),
                 "theta_head": theta_head}
    return out.astype(q.dtype), stats


# --------------------------------------------------------------- full layer
def build_attn_call(cfg, *, mode: str, paged: bool = False,
                    per_slot: bool = False, self_aligned: bool = False,
                    cross: bool = False, causal: bool = True,
                    collect_stats: bool = False, draft=None,
                    verify: bool = False,
                    kv_scale: str = "grid") -> AttnCall:
    """Construct the AttnCall `attn_apply` dispatches on.

    One place derives the static call descriptor from the model config and
    invocation shape — `attn_apply` uses it for dispatch, and the serving
    engine uses the SAME function to report the resolved backend per
    phase, so the report cannot drift from the dispatch.

    ``draft`` (a DraftProfile) marks a self-speculative draft step: its
    threshold overrides are folded into the call's HDP config here, so
    backends see exactly the grid the draft attends with. ``verify``
    marks a multi-query verify call (Sq > 1 decode — per-query-row scout
    semantics required of HDP backends).
    """
    hdp = cfg.hdp
    use_hdp = (hdp is not None and hdp.enabled
               and (mode != "train" or hdp.apply_in_training))
    eff_causal = causal and not cross
    window = 0 if cross else cfg.sliding_window
    hdp_eff = hdp.replace(causal=eff_causal) if use_hdp else None
    if draft is not None and hdp_eff is not None:
        hdp_eff = draft.overlay(hdp_eff)
    return AttnCall(
        mode="decode" if mode == "decode" else "prefill",
        layout="paged" if paged else "dense",
        causal=eff_causal,
        window=window,
        hdp=hdp_eff,
        per_slot=per_slot,
        self_aligned=self_aligned,
        trainable=mode == "train",
        chunk=cfg.attn_chunk,
        needs_stats=collect_stats,
        draft=draft if use_hdp else None,
        verify=verify and mode == "decode",
        kv_scale=kv_scale if paged else "grid",
    )


def attn_apply(cfg, p, x, *, mode: str, positions, cache=None,
               enc_out=None, causal: bool = True, static_cache: bool = False,
               collect_stats: bool = False, page_table=None,
               write_floor=None, draft=None,
               attn: Optional[AttnSpec] = None) -> Tuple[Any, Any, Any]:
    """Full MHA layer: project, rope, (HDP-)attend, output-project.

    mode: train | prefill | decode. cache: {"k","v"} [B,Smax,N,hd] (+ pos
    handled by caller passing `positions`). enc_out: cross-attention keys
    source (whisper decoder prefill); static_cache: attend to the cache
    as-is without writing (whisper cross-attn at decode). write_floor
    [B]: per-slot first-owned-page offset into the page table — a paged
    decode write whose page column sits below the floor would land in a
    *shared read-only* prefix page and is redirected to the scratch page
    instead (the prefix cache's immutability fence; the engine's COW
    keeps the fence un-hit in normal operation). draft: DraftProfile of a
    self-speculative draft step (None for full-fidelity calls). attn:
    backend selection spec (None -> the default spec, which honors the
    REPRO_ATTN_BACKEND env var); the attention maths itself is dispatched
    through ``repro.attention.attention`` on an AttnCall descriptor.
    Returns (y, new_cache, stats|None).

    Decode calls with S > 1 are multi-query *verify* calls (speculative
    decode): ``positions[:, j]`` must be consecutive per slot, every row's
    K/V is scattered into the cache before attention reads it, and HDP
    backends run their scout per query row.

    NOTE (perf log B3): writing K/V into the *stacked* [L,B,S,N,hd] cache
    before reading (to dodge the per-layer carry copy) was measured and
    REFUTED — two dynamic indices on a sequence-sharded buffer make the
    SPMD partitioner reshard the cache to replicated (memory_t 0.33 s ->
    2.6 s). The per-layer slice+update carry in transformer._stack is the
    best measured point.
    """
    B, S, D = x.shape
    H, N, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // N

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
    q = shd(q, "batch", "seq_act", "heads_act", None)
    if cfg.pos_emb == "rope" and enc_out is None and not static_cache:
        q = L.apply_rope(q, positions, cfg.rope_theta)

    new_cache = cache
    if static_cache:
        # cross-attention at decode: keys were cached at prefill
        k_full, v_full = cache["k"], cache["v"]
        k_pos = jnp.arange(k_full.shape[1])
    else:
        kv_src = enc_out if enc_out is not None else x
        k = jnp.einsum("bsd,dnk->bsnk", kv_src, p["wk"])
        v = jnp.einsum("bsd,dnk->bsnk", kv_src, p["wv"])
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        if cfg.qk_norm:
            k = L.rms_norm(k, p["k_norm"])
        k = shd(k, "batch", "seq_act", "kv_heads", None)
        if cfg.pos_emb == "rope" and enc_out is None:
            k = L.apply_rope(k, positions, cfg.rope_theta)

        if (attn is not None and attn.kv_dtype in ("int8", "fp8_v")
                and getattr(attn, "kv_scale", "grid") != "absmax"
                and mode == "prefill" and enc_out is None
                and cache is not None and "k_pages" not in cache):
            # quantized-pool engine prefilling its dense REQUEST cache:
            # round-trip K/V through the pool grid BEFORE the write, so
            # prefill attention (which reads this cache), the pool insert
            # (exact encode of these values), prefix-cache gathers and
            # COW tails all see one set of values — hot and cold runs
            # stay token-identical, and only the fp32-vs-int8 A/B sees
            # quantization drift. Calibrated (absmax) pools skip this:
            # their per-page scales depend on the values actually
            # inserted, so no write-time snap can anticipate them —
            # hot/cold bit parity is forfeited by that mode's contract
            # and the fp32 drift gate bounds the error instead
            ib = pool_int_bits(cfg.hdp)
            k = roundtrip_pool(k, ib).astype(k.dtype)
            if attn.kv_dtype == "fp8_v":
                v = v.astype(jnp.float8_e4m3fn).astype(v.dtype)
            else:
                v = roundtrip_pool(v, ib).astype(v.dtype)

        if cache is not None and "k_pages" in cache:
            # block-paged serving cache (decode only): scatter the S
            # tokens' K/V (+ int8 scout copy) into their slots' pages
            # (S > 1 = speculative verify — one scatter, then one
            # attention over the pool), then attend through the table.
            assert mode == "decode" and positions.ndim == 2, \
                "paged cache is a decode-time serving layout"
            ps = cache["k_pages"].shape[1]
            nP = page_table.shape[1]
            pidx = resolve_write_pages(positions, page_table, ps,
                                       write_floor)
            off = positions % ps
            pool_q = cache["k_pages"].dtype == jnp.int8
            kv_scale = getattr(attn, "kv_scale", "grid") if attn else "grid"
            if pool_q and kv_scale == "absmax":
                # calibrated pool: encode against the destination page's
                # CURRENT scale (set by the prefill insert; fresh decode
                # pages keep the static step), sanitizing NaN freed-page
                # poison back to the static step so the encode is finite
                ib = pool_int_bits(cfg.hdp)
                s0 = pool_scale(ib)
                ks = cache["k_scale"][pidx]                    # [B,S,N]
                ks = jnp.where(jnp.isfinite(ks), ks, s0)[..., None]
                k_store = encode_pool_scaled(k, ks)
                if cache["v_pages"].dtype != jnp.int8:
                    v_store = v.astype(cache["v_pages"].dtype)
                else:
                    vs = cache["v_scale"][pidx]
                    vs = jnp.where(jnp.isfinite(vs), vs, s0)[..., None]
                    v_store = encode_pool_scaled(v, vs)
            elif pool_q:
                ib = pool_int_bits(cfg.hdp)
                k_store = encode_pool(k, ib)
                v_store = (v.astype(cache["v_pages"].dtype)
                           if cache["v_pages"].dtype != jnp.int8
                           else encode_pool(v, ib))
            else:
                k_store = k.astype(cache["k_pages"].dtype)
                v_store = v.astype(cache["v_pages"].dtype)
            new_cache = {**cache,
                         "k_pages": cache["k_pages"].at[pidx, off].set(
                             k_store),
                         "v_pages": cache["v_pages"].at[pidx, off].set(
                             v_store)}
            if not pool_q and draft is not None \
                    and draft.scores != "approx" \
                    and cfg.hdp is not None and cfg.hdp.enabled:
                # a scout-scores draft neither reads nor needs the
                # full-precision K it would stage: later draft steps
                # score against the scout copies, and the verify rewrites
                # every staged position with exact K before anything else
                # can read it — skip the dead scatter. Gated on HDP like
                # the call descriptor (build_attn_call nulls draft
                # without a scout): the HDP-off degraded draft runs
                # exact attention and DOES read this K. A QUANTIZED pool
                # inverts the optimization: the codes ARE the scout copy
                # later draft steps stream, so the scatter is live
                new_cache["k_pages"] = cache["k_pages"]
            if "k_scout" in cache:
                new_cache["k_scout"] = cache["k_scout"].at[pidx, off].set(
                    scout_int8(k, cfg.hdp))
            if "f_scout" in cache:
                new_cache["f_scout"] = cache["f_scout"].at[pidx, off].set(
                    scout_frac_int8(k, cfg.hdp))
            ar = jnp.arange(nP * ps)
            k_pos = jnp.where(ar[None, :] <= positions[:, -1:], ar, -1)
            k_pos = k_pos[:, None, None, :]              # [B,1,1,nP*ps]
            k_full = v_full = None  # gathered lazily (FUM) below
        elif cache is not None:
            if positions.ndim == 2 and enc_out is None:
                # per-slot positions (continuous batching): each sequence
                # writes its cache at its own offset
                def upd(c, kv, p0):
                    return jax.lax.dynamic_update_slice_in_dim(c, kv, p0, 0)
                new_cache = {
                    "k": jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype),
                                       positions[:, 0]),
                    "v": jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype),
                                       positions[:, 0]),
                }
                k_full, v_full = new_cache["k"], new_cache["v"]
                ar = jnp.arange(k_full.shape[1])
                k_pos = jnp.where(ar[None, :] <= positions[:, -1:], ar, -1)
                k_pos = k_pos[:, None, None, :]          # [B,1,1,Smax]
            else:
                pos0 = positions[0] if enc_out is None else 0
                new_cache = {
                    "k": jax.lax.dynamic_update_slice_in_dim(
                        cache["k"], k.astype(cache["k"].dtype), pos0, 1),
                    "v": jax.lax.dynamic_update_slice_in_dim(
                        cache["v"], v.astype(cache["v"].dtype), pos0, 1),
                }
                k_full, v_full = new_cache["k"], new_cache["v"]
                k_pos = jnp.arange(k_full.shape[1])
                if enc_out is None:
                    k_pos = jnp.where(k_pos <= positions[-1], k_pos, -1)
        else:
            k_full, v_full = k, v
            k_pos = (jnp.arange(k.shape[1]) if enc_out is not None
                     else positions)

    qg = q.reshape(B, S, N, G, hd).transpose(0, 2, 3, 1, 4)  # [B,N,G,S,hd]
    # per-slot positions carry a batch dim; align it with [B,N,G,Sq,Sk]
    q_pos = positions[:, None, None, :] if positions.ndim == 2 else positions

    is_cross = enc_out is not None or static_cache
    paged = cache is not None and "k_pages" in cache
    call = build_attn_call(
        cfg, mode=mode, paged=paged, per_slot=positions.ndim == 2,
        self_aligned=(cache is None and not is_cross and positions.ndim == 1),
        cross=is_cross, causal=causal, collect_stats=collect_stats,
        draft=draft if mode == "decode" else None,
        verify=mode == "decode" and S > 1 and not is_cross,
        kv_scale=getattr(attn, "kv_scale", "grid") if attn else "grid")
    mesh = None
    if paged:
        from repro.distribution.tp import active_serving_mesh
        mesh = active_serving_mesh()
    if mesh is not None:
        # tensor-parallel serving: run the paged-decode dispatch head-
        # sharded under the ambient mesh (per-shard scout + fetched set;
        # one exact all-gather of o before the projection below)
        from repro.distribution.tp import tp_paged_attention
        o, stats = tp_paged_attention(
            qg, call, attn, q_pos=q_pos, k_pos=k_pos, cache=new_cache,
            page_table=page_table, mesh=mesh)
    else:
        o, stats = attention(
            qg, k_full, v_full, call, spec=attn, q_pos=q_pos, k_pos=k_pos,
            cache=new_cache if paged else None, page_table=page_table)

    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    y = shd(y, "batch", "seq_act", "embed_act")
    return y, new_cache, stats
