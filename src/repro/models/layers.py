"""Common pure-JAX building blocks (no flax).

Every ``*_init`` returns ``(params, specs)`` where ``specs`` mirrors the
param tree with tuples of *logical axis names* — resolved to PartitionSpec
by distribution.sharding. Building both trees in one place keeps them
structurally identical by construction (asserted in tests).
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Specs = Dict[str, Any]


def key_for(rng: jax.Array, name: str) -> jax.Array:
    return jax.random.fold_in(rng, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def dense_init(rng, shape, dtype, in_axis: int = 0, scale: float = 1.0):
    fan_in = 1
    for a in (shape[in_axis:-1] if in_axis >= 0 else shape[:-1]):
        fan_in *= a
    fan_in = max(fan_in, 1)
    std = scale / (fan_in ** 0.5)
    return (std * jax.random.truncated_normal(rng, -2.0, 2.0, shape)).astype(dtype)


# ------------------------------------------------------------------- norms
def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_init(cfg, dtype) -> Tuple[Params, Specs]:
    if cfg.norm == "layernorm":
        return ({"w": jnp.ones((cfg.d_model,), dtype),
                 "b": jnp.zeros((cfg.d_model,), dtype)},
                {"w": ("embed",), "b": ("embed",)})
    return ({"w": jnp.ones((cfg.d_model,), dtype)}, {"w": ("embed",)})


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


def group_norm_heads(x, w, b, eps=1e-5):
    """Per-head group norm for RWKV: x [..., H, hd]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------- position codes
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [S] or [..., S] (absolute)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], -1).astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset=0) -> jnp.ndarray:
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return out


# --------------------------------------------------------------------- MLPs
def mlp_init(cfg, rng, dtype) -> Tuple[Params, Specs]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu_glu":
        p = {"w_gate": dense_init(key_for(rng, "w_gate"), (d, f), dtype),
             "w_up": dense_init(key_for(rng, "w_up"), (d, f), dtype),
             "w_down": dense_init(key_for(rng, "w_down"), (f, d), dtype)}
        s = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
             "w_down": ("mlp", "embed")}
    elif cfg.act in ("gelu", "relu2"):
        p = {"w1": dense_init(key_for(rng, "w1"), (d, f), dtype),
             "w2": dense_init(key_for(rng, "w2"), (f, d), dtype)}
        s = {"w1": ("embed", "mlp"), "w2": ("mlp", "embed")}
        if cfg.act == "gelu":  # whisper-style biases
            p["b1"] = jnp.zeros((f,), dtype)
            p["b2"] = jnp.zeros((d,), dtype)
            s["b1"] = ("mlp",)
            s["b2"] = ("embed",)
    else:
        raise ValueError(f"unknown act {cfg.act}")
    return p, s


def mlp_apply(cfg, p, x):
    from repro.distribution.sharding import shard_activation as shd
    if cfg.act == "silu_glu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shd(h, "batch", None, "mlp_act")
        return h @ p["w_down"]
    h = x @ p["w1"]
    if cfg.act == "gelu":
        h = jax.nn.gelu(h + p["b1"], approximate=True)
        h = shd(h, "batch", None, "mlp_act")
        return h @ p["w2"] + p["b2"]
    # relu2 (nemotron-4): squared ReLU, no bias
    h = jnp.square(jax.nn.relu(h))
    h = shd(h, "batch", None, "mlp_act")
    return h @ p["w2"]


# --------------------------------------------------------------- embeddings
def embed_init(cfg, rng, dtype) -> Tuple[Params, Specs]:
    # the d_model dim of the vocab tables uses `table_embed` (never
    # FSDP-sharded over data): data-sharding it makes the logits matmul
    # all-gather a full [d, vocab] f32 table per device, which XLA then
    # hoists into the loop carry — 4 GB live for a 200k vocab. The
    # vocab->model sharding already splits the table 16-way.
    p = {"tok": dense_init(key_for(rng, "tok_embed"),
                           (cfg.vocab_size, cfg.d_model), dtype, scale=1.0)}
    s = {"tok": ("vocab", "table_embed")}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(key_for(rng, "lm_head"),
                                  (cfg.d_model, cfg.vocab_size), dtype)
        s["lm_head"] = ("table_embed", "vocab")
    return p, s


def embed_tokens(p, tokens, d_model):
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p, x):
    w = p.get("lm_head")
    if w is None:
        w = p["tok"].T
    return (x @ w).astype(jnp.float32)


def lm_logits_sharded(p, x):
    """Final-projection logits with the vocab dim kept on `model`.

    When activations are sequence-sharded over `model` (head-indivisible
    archs, prefill context parallelism) the vocab dim would lose its mesh
    axis and the lm_head matmul + its grad materialize FULL [d, vocab]
    f32 buffers with 4 GB all-reduces. Regrouping the (cheap, [B,S,D]
    bf16) activations first keeps all vocab math model-sharded.
    """
    from repro.distribution.sharding import shard_activation as shd
    x = shd(x, "batch", None, "embed_act")
    logits = lm_logits(p, x)
    return shd(logits, "batch", None, "vocab_act")
