"""Whisper-large-v3 backbone (encoder-decoder, audio).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, S_enc, d_model]; a learned linear adapter
stands in for the conv stack. Decoder positions use sinusoidal encoding
(adaptation from whisper's learned table, which is sized 448 — too small
for the assigned 32k decode shape; noted in DESIGN.md).

HDP applies to encoder self-attention and decoder self/cross-attention.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L
from repro.models.attention import attn_apply, attn_init

F32 = jnp.float32


def _enc_layer_init(cfg, rng, dtype):
    attn_p, attn_s = attn_init(cfg, L.key_for(rng, "attn"), dtype)
    ln1, ln1s = L.norm_init(cfg, dtype)
    ln2, ln2s = L.norm_init(cfg, dtype)
    mlp_p, mlp_s = L.mlp_init(cfg, L.key_for(rng, "mlp"), dtype)
    return ({"attn": attn_p, "ln1": ln1, "ln2": ln2, "mlp": mlp_p},
            {"attn": attn_s, "ln1": ln1s, "ln2": ln2s, "mlp": mlp_s})


def _dec_layer_init(cfg, rng, dtype):
    self_p, self_s = attn_init(cfg, L.key_for(rng, "self"), dtype)
    cross_p, cross_s = attn_init(cfg, L.key_for(rng, "cross"), dtype)
    lns = [L.norm_init(cfg, dtype) for _ in range(3)]
    mlp_p, mlp_s = L.mlp_init(cfg, L.key_for(rng, "mlp"), dtype)
    return ({"self": self_p, "cross": cross_p, "mlp": mlp_p,
             "ln1": lns[0][0], "ln2": lns[1][0], "ln3": lns[2][0]},
            {"self": self_s, "cross": cross_s, "mlp": mlp_s,
             "ln1": lns[0][1], "ln2": lns[1][1], "ln3": lns[2][1]})


def _stacked(init_fn, cfg, rng, n, dtype):
    keys = jax.random.split(rng, n)
    params = jax.vmap(lambda k: init_fn(cfg, k, dtype)[0])(keys)
    _, s = init_fn(cfg, keys[0], dtype)
    specs = jax.tree.map(lambda ax: ("layers",) + tuple(ax), s,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


def init_params(cfg, rng) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    emb_p, emb_s = L.embed_init(cfg, L.key_for(rng, "embed"), dtype)
    front_p = {"w": L.dense_init(L.key_for(rng, "front"),
                                 (cfg.d_model, cfg.d_model), dtype)}
    enc_p, enc_s = _stacked(_enc_layer_init, cfg, L.key_for(rng, "enc"),
                            cfg.encoder_layers, dtype)
    dec_p, dec_s = _stacked(_dec_layer_init, cfg, L.key_for(rng, "dec"),
                            cfg.decoder_layers, dtype)
    ln_enc, ln_enc_s = L.norm_init(cfg, dtype)
    ln_dec, ln_dec_s = L.norm_init(cfg, dtype)
    return ({"embed": emb_p, "frontend": front_p, "enc": enc_p, "dec": dec_p,
             "ln_enc": ln_enc, "ln_dec": ln_dec},
            {"embed": emb_s, "frontend": {"w": ("embed", "embed")},
             "enc": enc_s, "dec": dec_s,
             "ln_enc": ln_enc_s, "ln_dec": ln_dec_s})


def encode(cfg, params, frames, *, collect_stats=False):
    """frames [B,S,D] (stub embeddings) -> encoder states [B,S,D]."""
    x = frames @ params["frontend"]["w"]
    x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    x = shd(x, "batch", "seq_act", "embed_act")
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        h = L.apply_norm(cfg, lp["ln1"], carry)
        a, _, st = attn_apply(cfg, lp["attn"], h, mode="train",
                              positions=positions, causal=False,
                              collect_stats=collect_stats)
        x = carry + a
        h = L.apply_norm(cfg, lp["ln2"], x)
        return x + L.mlp_apply(cfg, lp["mlp"], h), st

    if cfg.remat:
        body = jax.checkpoint(body)
    x, stats = jax.lax.scan(body, x, params["enc"])
    return L.apply_norm(cfg, params["ln_enc"], x), stats


def _decoder(cfg, params, tokens, enc_out, cache, positions, mode,
             collect_stats=False, attn=None):
    x = L.embed_tokens(params["embed"], tokens, cfg.d_model)
    x = x + L.sinusoidal_pos(tokens.shape[1], cfg.d_model,
                             offset=positions[0]).astype(x.dtype)
    x = shd(x, "batch", "seq_act", "embed_act")
    has_cache = cache is not None

    def layer(lp, lc, x):
        h = L.apply_norm(cfg, lp["ln1"], x)
        a, new_self, st = attn_apply(
            cfg, lp["self"], h, mode=mode, positions=positions,
            cache=lc["self"] if lc else None, collect_stats=collect_stats,
            attn=attn)
        x = x + a
        h = L.apply_norm(cfg, lp["ln2"], x)
        if mode == "decode":
            c, new_cross, _ = attn_apply(
                cfg, lp["cross"], h, mode=mode, positions=positions,
                cache=lc["cross"], static_cache=True, attn=attn)
        else:
            c, new_cross, _ = attn_apply(
                cfg, lp["cross"], h, mode=mode, positions=positions,
                cache=lc["cross"] if lc else None, enc_out=enc_out,
                attn=attn)
        x = x + c
        h = L.apply_norm(cfg, lp["ln3"], x)
        x = x + L.mlp_apply(cfg, lp["mlp"], h)
        return x, new_self, new_cross, st

    if not has_cache:
        def body(carry, lp):
            x, _, _, st = layer(lp, None, carry)
            return x, st

        if cfg.remat:
            body = jax.checkpoint(body)
        x, stats = jax.lax.scan(body, x, params["dec"])
        return L.apply_norm(cfg, params["ln_dec"], x), None, stats

    # inference: caches ride the carry with per-layer in-place updates
    # (stacked scan `ys` would allocate a second full cache buffer); the
    # cross cache is static at decode, so it is never rewritten there.
    def body(carry, lp):
        x, cache_all, li = carry
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
            cache_all)
        x, new_self, new_cross, st = layer(lp, lc, x)

        def put(c, n):
            return jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), li, 0)

        cache_all = dict(cache_all)
        cache_all["self"] = jax.tree.map(put, cache_all["self"], new_self)
        if mode != "decode":
            cache_all["cross"] = jax.tree.map(put, cache_all["cross"],
                                              new_cross)
        return (x, cache_all, li + 1), st

    (x, new_cache, _), stats = jax.lax.scan(
        body, (x, cache, jnp.asarray(0, jnp.int32)), params["dec"])
    return L.apply_norm(cfg, params["ln_dec"], x), new_cache, stats


def apply_train(cfg, params, batch, *, collect_stats: bool = False):
    enc_out, _ = encode(cfg, params, batch["frames"],
                        collect_stats=collect_stats)
    positions = jnp.arange(batch["tokens"].shape[1])
    x, _, stats = _decoder(cfg, params, batch["tokens"], enc_out, None,
                           positions, "train", collect_stats)
    logits = L.lm_logits_sharded(params["embed"], x)
    return logits, {"aux_loss": jnp.zeros((), F32), "hdp": stats}


def init_cache(cfg, batch: int, max_len: int, dtype=None,
               enc_len: int = 0) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    n, hd, dl = cfg.n_kv_heads, cfg.hd, cfg.decoder_layers
    enc_len = enc_len or cfg.max_source_positions or 1500
    return {
        "self": {"k": jnp.zeros((dl, batch, max_len, n, hd), dt),
                 "v": jnp.zeros((dl, batch, max_len, n, hd), dt)},
        "cross": {"k": jnp.zeros((dl, batch, enc_len, n, hd), dt),
                  "v": jnp.zeros((dl, batch, enc_len, n, hd), dt)},
    }


def cache_specs(cfg) -> Dict:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"self": {"k": ax, "v": ax}, "cross": {"k": ax, "v": ax}}


def apply_prefill(cfg, params, batch, cache, *, collect_stats: bool = False,
                  attn=None):
    """Encode audio, prime decoder on prompt tokens, fill both caches."""
    enc_out, _ = encode(cfg, params, batch["frames"],
                        collect_stats=collect_stats)
    positions = jnp.arange(batch["tokens"].shape[1])
    x, new_cache, stats = _decoder(cfg, params, batch["tokens"], enc_out,
                                   cache, positions, "prefill",
                                   collect_stats, attn=attn)
    logits = L.lm_logits_sharded(params["embed"], x[:, -1:])
    return logits, new_cache, stats


def apply_decode(cfg, params, token, cache, pos, *, collect_stats: bool = False,
                 attn=None):
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    x, new_cache, stats = _decoder(cfg, params, token, None, cache,
                                   positions, "decode", collect_stats,
                                   attn=attn)
    logits = L.lm_logits(params["embed"], x)
    return logits, new_cache, stats


def param_count(cfg) -> int:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d + 3 * (cfg.n_heads * hd + cfg.n_kv_heads * hd)
    mlp = 2 * d * f + f + d
    enc = cfg.encoder_layers * (attn + mlp + 4 * d)
    dec = cfg.decoder_layers * (2 * attn + mlp + 6 * d)
    return enc + dec + cfg.vocab_size * d + d * d + 2 * d
