"""Family dispatch: one uniform API over all architectures.

  init_params(cfg, rng)        -> (params, logical_specs)
  apply_train(cfg, p, batch)   -> (logits, {"aux_loss", "hdp"})
  init_cache(cfg, B, max_len)  -> cache pytree
  cache_specs(cfg)             -> logical specs for the cache
  apply_prefill / apply_decode -> serving steps
  input_specs(cfg, shape)      -> ShapeDtypeStruct stand-ins (dry-run)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import rwkv6, transformer, whisper, zamba2

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "rwkv6": rwkv6,
    "zamba2": zamba2,
    "whisper": whisper,
}


def module_for(cfg: ModelConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r}") from None


def init_params(cfg, rng):
    return module_for(cfg).init_params(cfg, rng)


def abstract_params(cfg, rng=None):
    """eval_shape'd params — no device allocation (dry-run path)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(
        lambda r: module_for(cfg).init_params(cfg, r)[0], rng)
    return shapes, param_specs(cfg)


def param_specs(cfg):
    """Logical specs tree (no array allocation — mirrors init structure)."""
    rng = jax.random.PRNGKey(0)
    # init under eval_shape so nothing is materialized; specs are static.
    out = {}

    def capture(r):
        p, s = module_for(cfg).init_params(cfg, r)
        out["specs"] = s
        return p

    jax.eval_shape(capture, rng)
    return out["specs"]


def apply_train(cfg, params, batch, **kw):
    return module_for(cfg).apply_train(cfg, params, batch, **kw)


def init_cache(cfg, batch: int, max_len: int, dtype=None, **kw):
    return module_for(cfg).init_cache(cfg, batch, max_len, dtype=dtype, **kw)


def cache_specs(cfg):
    m = module_for(cfg)
    try:
        return m.cache_specs(cfg)
    except TypeError:
        return m.cache_specs()


def apply_prefill(cfg, params, batch, cache, **kw):
    return module_for(cfg).apply_prefill(cfg, params, batch, cache, **kw)


def apply_decode(cfg, params, token, cache, pos, **kw):
    return module_for(cfg).apply_decode(cfg, params, token, cache, pos, **kw)


def param_count(cfg, active_only: bool = False) -> int:
    m = module_for(cfg)
    if active_only and hasattr(m, "active_param_count"):
        return m.active_param_count(cfg)
    return m.param_count(cfg)


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {"batch": {"tokens" [B,S]} (+frames for audio)}
    prefill: {"batch": {...}}
    decode:  {"token" [B,1], "pos" scalar}  (cache specs come from
             init_cache via eval_shape in the dry-run)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok = lambda *s: jax.ShapeDtypeStruct(s, i32)
    act = lambda *s: jax.ShapeDtypeStruct(s, jnp.dtype(cfg.dtype))

    if cfg.is_encoder_decoder:
        dec_len = max(S // 8, 8)
        if shape.kind == "train":
            return {"batch": {"frames": act(B, S, cfg.d_model),
                              "tokens": tok(B, dec_len)}}
        if shape.kind == "prefill":
            return {"batch": {"frames": act(B, S, cfg.d_model),
                              "tokens": tok(B, dec_len)}}
        return {"token": tok(B, 1), "pos": jax.ShapeDtypeStruct((), i32)}

    if shape.kind in ("train", "prefill"):
        return {"batch": {"tokens": tok(B, S)}}
    return {"token": tok(B, 1), "pos": jax.ShapeDtypeStruct((), i32)}


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV length the decode cell must hold (ring-buffered for SWA)."""
    if cfg.sliding_window:
        return min(shape.seq_len, max(cfg.sliding_window * 2, 16))
    return shape.seq_len
