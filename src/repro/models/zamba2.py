"""Zamba2 hybrid: Mamba2 backbone + ONE shared attention block (with
per-invocation LoRA) applied every `attn_every` layers on
concat(hidden, original embedding) — the architecture's hallmark weight
sharing [arXiv:2411.15242].

HDP applies to the shared attention block only; Mamba2 blocks are
attention-free (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard_activation as shd
from repro.models import layers as L
from repro.models import mamba2
from repro.models.attention import attn_apply, attn_init

F32 = jnp.float32
LORA_R = 16


def _n_groups(cfg) -> int:
    return cfg.n_layers // cfg.attn_every


def _n_tail(cfg) -> int:
    return cfg.n_layers % cfg.attn_every


def _shared_cfg(cfg):
    """The shared block runs at width 2*d_model (concat input)."""
    return cfg.replace(d_model=2 * cfg.d_model, sliding_window=0,
                       qkv_bias=False, qk_norm=False, n_experts=0)


def _shared_init(cfg, rng, dtype) -> Tuple[Dict, Dict]:
    scfg = _shared_cfg(cfg)
    attn_p, attn_s = attn_init(scfg, L.key_for(rng, "attn"), dtype)
    d2, d, f = 2 * cfg.d_model, cfg.d_model, cfg.d_ff
    g = _n_groups(cfg)
    h, hd = cfg.n_heads, cfg.hd
    p = {
        "attn": attn_p,
        "ln1": {"w": jnp.ones((d2,), dtype)},
        "ln2": {"w": jnp.ones((d2,), dtype)},
        "mlp": {"w_gate": L.dense_init(L.key_for(rng, "mg"), (d2, f), dtype),
                "w_up": L.dense_init(L.key_for(rng, "mu"), (d2, f), dtype),
                "w_down": L.dense_init(L.key_for(rng, "md"), (f, d2), dtype)},
        "proj_out": L.dense_init(L.key_for(rng, "po"), (d2, d), dtype),
        # per-invocation LoRA deltas on wq/wk/wv (stacked over groups)
        "lora_A": L.dense_init(L.key_for(rng, "lA"), (g, 3, d2, LORA_R), dtype,
                               in_axis=2),
        "lora_B": jnp.zeros((g, 3, LORA_R, h * hd), dtype),
    }
    s = {
        "attn": attn_s,
        "ln1": {"w": ("embed",)}, "ln2": {"w": ("embed",)},
        "mlp": {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")},
        "proj_out": ("embed", "embed"),
        "lora_A": ("groups", None, "embed", None),
        "lora_B": ("groups", None, None, "heads"),
    }
    return p, s


def init_params(cfg, rng) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    emb_p, emb_s = L.embed_init(cfg, L.key_for(rng, "embed"), dtype)
    g, a, t = _n_groups(cfg), cfg.attn_every, _n_tail(cfg)

    def one_mamba(k):
        mp, _ = mamba2.layer_init(cfg, k, dtype)
        lnp, _ = L.norm_init(cfg, dtype)
        return {"m": mp, "ln": lnp}

    _, m_s = mamba2.layer_init(cfg, rng, dtype)
    _, ln_s = L.norm_init(cfg, dtype)
    keys = jax.random.split(L.key_for(rng, "mamba"), g * a).reshape(g, a, 2)
    grouped = jax.vmap(jax.vmap(one_mamba))(keys)
    grouped_s = jax.tree.map(lambda ax: ("groups", "layers") + tuple(ax),
                             {"m": m_s, "ln": ln_s},
                             is_leaf=lambda x: isinstance(x, tuple))
    params = {"embed": emb_p, "grouped": grouped}
    specs = {"embed": emb_s, "grouped": grouped_s}
    if t:
        tkeys = jax.random.split(L.key_for(rng, "tail"), t)
        params["tail"] = jax.vmap(one_mamba)(tkeys)
        specs["tail"] = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                                     {"m": m_s, "ln": ln_s},
                                     is_leaf=lambda x: isinstance(x, tuple))
    sh_p, sh_s = _shared_init(cfg, L.key_for(rng, "shared"), dtype)
    fin_p, fin_s = L.norm_init(cfg, dtype)
    params.update(shared=sh_p, final_norm=fin_p)
    specs.update(shared=sh_s, final_norm=fin_s)
    return params, specs


def _apply_shared(cfg, p, h, emb0, lora_a, lora_b, *, mode, positions,
                  cache, collect_stats, attn=None):
    """One invocation of the shared block; returns (h', new_cache, stats)."""
    scfg = _shared_cfg(cfg)
    x = jnp.concatenate([h, emb0], axis=-1)
    hln = L.rms_norm(x, p["ln1"]["w"])
    # LoRA-specialized qkv for this invocation
    H, hd = cfg.n_heads, cfg.hd
    d2 = 2 * cfg.d_model
    attn_p = dict(p["attn"])
    for i, w in enumerate(("wq", "wk", "wv")):
        delta = (lora_a[i] @ lora_b[i]).reshape(d2, *attn_p[w].shape[1:])
        attn_p[w] = attn_p[w] + delta
    a, new_cache, stats = attn_apply(scfg, attn_p, hln, mode=mode,
                                     positions=positions, cache=cache,
                                     collect_stats=collect_stats, attn=attn)
    x = x + a
    hln = L.rms_norm(x, p["ln2"]["w"])
    m = jax.nn.silu(hln @ p["mlp"]["w_gate"]) * (hln @ p["mlp"]["w_up"])
    x = x + m @ p["mlp"]["w_down"]
    return h + x @ p["proj_out"], new_cache, stats


def _run(cfg, params, tokens_or_x, *, mode, positions, cache, collect_stats,
         attn=None):
    if tokens_or_x.ndim == 2:
        x = L.embed_tokens(params["embed"], tokens_or_x, cfg.d_model)
    else:
        x = tokens_or_x
    x = shd(x, "batch", "seq_act", "embed_act")
    emb0 = x
    g = _n_groups(cfg)
    has_cache = cache is not None

    def mamba_stack(x, mp, mcache):
        def body(carry, xs):
            lp = xs[0] if has_cache else xs
            lc = xs[1] if has_cache else None
            hln = L.apply_norm(cfg, lp["ln"], carry)
            y, nc = mamba2.layer_apply(cfg, lp["m"], hln, lc)
            return carry + y, nc
        body = jax.checkpoint(body) if cfg.remat else body
        xs = (mp, mcache) if has_cache else mp
        return jax.lax.scan(body, x, xs)

    xs = {"mp": params["grouped"], "lora_a": params["shared"]["lora_A"],
          "lora_b": params["shared"]["lora_B"]}

    if not has_cache:
        def group_body(carry, xs_g):
            x, _ = carry
            x, _mc = mamba_stack(x, xs_g["mp"], None)
            x, _ac, stats = _apply_shared(cfg, params["shared"], x, emb0,
                                          xs_g["lora_a"], xs_g["lora_b"],
                                          mode=mode, positions=positions,
                                          cache=None, attn=attn,
                                          collect_stats=collect_stats)
            return (x, 0), stats

        # remat the whole group too: without it the backward saves every
        # group-iteration intermediate as a [n_groups, ...] stack
        # (attention slabs, f32 mamba projections) — 20+ GB at 4k train
        gbody = jax.checkpoint(group_body) if cfg.remat else group_body
        (x, _), stats = jax.lax.scan(gbody, (x, 0), xs)
        new_cache = None
    else:
        # inference: caches ride the carry with per-group in-place
        # updates (stacked scan ys = a second full KV-cache allocation)
        def group_body(carry, xs_g):
            x, cache_all, gi = carry
            take = lambda c: jax.lax.dynamic_index_in_dim(  # noqa: E731
                c, gi, 0, keepdims=False)
            put = lambda c, n: jax.lax.dynamic_update_index_in_dim(  # noqa: E731,E501
                c, n.astype(c.dtype), gi, 0)
            x, new_mc = mamba_stack(x, xs_g["mp"],
                                    jax.tree.map(take, cache_all["mamba"]))
            x, new_ac, stats = _apply_shared(
                cfg, params["shared"], x, emb0, xs_g["lora_a"],
                xs_g["lora_b"], mode=mode, positions=positions,
                cache=jax.tree.map(take, cache_all["attn"]),
                collect_stats=collect_stats, attn=attn)
            cache_all = {
                "mamba": jax.tree.map(put, cache_all["mamba"], new_mc),
                "attn": jax.tree.map(put, cache_all["attn"], new_ac),
            }
            return (x, cache_all, gi + 1), stats

        (x, new_cache, _), stats = jax.lax.scan(
            group_body,
            (x, {"mamba": cache["mamba"], "attn": cache["attn"]},
             jnp.asarray(0, jnp.int32)),
            xs)

    if _n_tail(cfg):
        tc = cache["tail"] if has_cache else None
        x, new_tc = mamba_stack(x, params["tail"], tc)
        if has_cache:
            new_cache["tail"] = new_tc
    return x, new_cache, stats


def apply_train(cfg, params, batch, *, collect_stats: bool = False):
    x, _, stats = _run(cfg, params, batch["tokens"], mode="train",
                       positions=jnp.arange(batch["tokens"].shape[1]),
                       cache=None, collect_stats=collect_stats)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits_sharded(params["embed"], x)
    return logits, {"aux_loss": jnp.zeros((), F32), "hdp": stats}


def init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict:
    g, a, t = _n_groups(cfg), cfg.attn_every, _n_tail(cfg)
    dt = jnp.dtype(dtype or cfg.dtype)
    one_m = mamba2.init_cache(cfg, batch, dtype)
    cache = {
        "mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g, a) + x.shape), one_m),
        "attn": {
            "k": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        },
    }
    if t:
        cache["tail"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (t,) + x.shape), one_m)
    return cache


def cache_specs(cfg) -> Dict:
    mspec = jax.tree.map(lambda ax: ("groups", "layers") + tuple(ax),
                         mamba2.cache_specs(),
                         is_leaf=lambda x: isinstance(x, tuple))
    out = {"mamba": mspec,
           "attn": {"k": ("groups", "batch", "kv_seq", "kv_heads", "head_dim"),
                    "v": ("groups", "batch", "kv_seq", "kv_heads", "head_dim")}}
    if _n_tail(cfg):
        out["tail"] = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                                   mamba2.cache_specs(),
                                   is_leaf=lambda x: isinstance(x, tuple))
    return out


def apply_prefill(cfg, params, batch, cache, *, collect_stats: bool = False,
                  attn=None):
    tokens = batch["tokens"]
    x, new_cache, stats = _run(cfg, params, tokens, mode="prefill",
                               positions=jnp.arange(tokens.shape[1]),
                               cache=cache, collect_stats=collect_stats,
                               attn=attn)
    x = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    return L.lm_logits_sharded(params["embed"], x), new_cache, stats


def apply_decode(cfg, params, token, cache, pos, *, collect_stats: bool = False,
                 attn=None):
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    x, new_cache, stats = _run(cfg, params, token, mode="decode",
                               positions=positions, cache=cache,
                               collect_stats=collect_stats, attn=attn)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_logits(params["embed"], x), new_cache, stats


def param_count(cfg) -> int:
    d, d2, f = cfg.d_model, 2 * cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.hd
    g = _n_groups(cfg)
    mamba = cfg.n_layers * (mamba2.param_count(cfg) + d)
    shared = (d2 * h * hd + 2 * d2 * cfg.n_kv_heads * hd + h * hd * d2
              + 2 * d2 + 3 * d2 * f // 1 + d2 * d
              + g * 3 * (d2 * LORA_R + LORA_R * h * hd))
    return mamba + shared + cfg.vocab_size * d * 2 + d
