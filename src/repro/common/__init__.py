"""Cross-cutting helpers shared by training and serving."""

from repro.common.transient import TransientError, is_transient

__all__ = ["TransientError", "is_transient"]
