"""Shared transient-error taxonomy for training and serving retries.

A *transient* failure is one that is expected under load and safe to
retry or defer: pool pressure, collective timeouts, network hiccups,
preemption. Everything else — assertion failures, shape errors, donated
handles, injected chaos faults — is a programming error and must fail
fast instead of burning retry budget masking the bug.

Raise :class:`TransientError` (or a subclass) to mark a failure as
retryable by construction. :func:`is_transient` classifies arbitrary
exceptions: typed ``TransientError``s and OS-level errors are transient;
bare ``RuntimeError``s are transient only when their message matches a
known-transient pattern (XLA surfaces collective timeouts and resource
exhaustion as plain RuntimeErrors, so a message filter is the only
handle on them).
"""
from __future__ import annotations

# Substrings (lowercased) that mark a bare RuntimeError as transient.
# These are the shapes XLA / distributed runtimes actually produce for
# recoverable conditions; anything not matching fails fast.
TRANSIENT_PATTERNS = (
    "timeout",
    "timed out",
    "unavailable",
    "connection",
    "collective",
    "resource exhausted",
    "resource_exhausted",
    "deadline exceeded",
    "deadline_exceeded",
    "preempted",
    "temporarily",
    "pool exhausted",
)


class TransientError(RuntimeError):
    """A failure expected under load and safe to retry or defer."""


def is_transient(exc: BaseException) -> bool:
    """True when ``exc`` is safe to retry (typed transient, OS-level, or
    a bare RuntimeError whose message matches a known-transient shape)."""
    if isinstance(exc, TransientError):
        return True
    if isinstance(exc, (OSError, TimeoutError)):
        return True
    if type(exc) is RuntimeError:
        msg = str(exc).lower()
        return any(p in msg for p in TRANSIENT_PATTERNS)
    return False
