from repro.roofline import analysis, hardware
from repro.roofline.hardware import (HOST_CPU, TPU_V5E, HardwareProfile,
                                     detect_profile, get_profile)

__all__ = ["analysis", "hardware", "HardwareProfile", "TPU_V5E", "HOST_CPU",
           "detect_profile", "get_profile"]
