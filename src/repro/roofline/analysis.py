"""Three-term roofline from compiled dry-run artifacts.

  compute_t    = HLO_FLOPs(per-device program) / peak_FLOP/s
  memory_t     = HLO bytes accessed            / HBM bandwidth
  collective_t = collective operand bytes      / ICI link bandwidth

FLOPs / bytes / collective bytes come from :mod:`repro.roofline.hlo_cost`,
a **while-aware** HLO cost model: ``compiled.cost_analysis()`` counts scan
bodies once (undercounting layer-scanned + grad-accumulated programs by
~``n_layers * num_microbatches``), so it is kept only as a cross-check
field (``xla_flops``).  Collective bytes are parsed from the compiled HLO
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-
permute operand shapes, trip-multiplied) since XLA does not report them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.roofline import hlo_cost
from repro.roofline.hardware import TPU_V5E, HardwareProfile

# TPU v5e constants (per chip) — kept under their historical names for
# launch/dryrun.py and benchmarks/decode_roofline.py; the values now
# live in roofline/hardware.py as pluggable HardwareProfiles.
PEAK_FLOPS = TPU_V5E.peak_flops      # bf16
HBM_BW = TPU_V5E.hbm_bw              # bytes/s
ICI_BW = TPU_V5E.ici_bw              # bytes/s per link (single-link)
HBM_BYTES = TPU_V5E.mem_bytes        # 16 GiB HBM2 capacity (binary, per
#                              spec); runtime reserve is ~100s of MB —
#                              cells within ~0.5 GB of the edge are
#                              flagged in EXPERIMENTS.md §Dry-run.


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    compute_t: float
    memory_t: float
    collective_t: float
    bottleneck: str
    peak_memory_bytes: Optional[float] = None
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None
    xla_flops: Optional[float] = None       # cost_analysis() cross-check
    top_flops: Optional[List] = None        # [(label, flops)] attribution
    top_bytes: Optional[List] = None
    hw: Optional[str] = None                # hardware profile the times use

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(compiled, *, model_flops_per_device: Optional[float] = None,
            keep_top: int = 8,
            hw: Optional[HardwareProfile] = None) -> Roofline:
    """model_flops_per_device: 6*N*D token-based FLOPs (global / n_devices).

    ``hw`` selects the hardware envelope the time terms divide by —
    default TPU v5e (the dry-run tables project the deploy target);
    pass ``hardware.detect_profile()`` to roofline the host itself.
    """
    prof = hw if hw is not None else TPU_V5E
    cost = hlo_cost.module_cost(compiled.as_text())
    flops, byts, cbytes = cost.flops, cost.bytes, cost.coll_bytes

    xla = None
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        xla = float(ca.get("flops", 0.0))
    except Exception:
        pass

    ct = flops / prof.peak_flops
    mt = byts / prof.hbm_bw
    lt = cbytes / prof.ici_bw
    bottleneck = max((("compute", ct), ("memory", mt), ("collective", lt)),
                     key=lambda kv: kv[1])[0]

    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception:
        pass

    ratio = (model_flops_per_device / flops
             if model_flops_per_device and flops else None)
    top = hlo_cost.top_contributors(cost, keep_top)
    return Roofline(flops, byts, cbytes,
                    {k: int(v) for k, v in cost.coll_by_kind.items()},
                    ct, mt, lt, bottleneck, peak,
                    model_flops_per_device, ratio, xla,
                    top["flops"], top["bytes"], prof.name)


def model_flops(cfg, shape, n_devices: int) -> float:
    """6*N_active*D per step (train: 3x for fwd+bwd is folded into the 6;
    inference: 2*N*D per token + 2*attention read of the KV cache)."""
    from repro.models import registry
    n_active = registry.param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


# kept for backward compatibility with earlier tests/benchmarks
def collective_bytes(hlo_text: str) -> Dict[str, int]:
    cost = hlo_cost.module_cost(hlo_text)
    return {k: int(v) for k, v in cost.coll_by_kind.items()}
