"""Pluggable hardware profiles for roofline and cost-model predictions.

The dry-run roofline tables always projected TPU v5e numbers from
module-level constants in ``analysis.py``; the serving autotuner
(``repro.autotune``) reuses the same constants to predict attention-
backend step times — but it runs wherever the engine runs, which in CI
and on dev machines is a CPU host. A cost prediction made with TPU
bandwidth on a CPU host is silently wrong in a way that flips backend
choices, so the constants live here as named profiles and
:func:`detect_profile` picks the one matching the actual JAX backend.

``analysis.py`` keeps re-exporting the TPU v5e numbers under their old
names (``PEAK_FLOPS`` / ``HBM_BW`` / ``ICI_BW`` / ``HBM_BYTES``): the
dry-run tables intentionally project the deploy target, not the host.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Per-chip performance envelope + dispatch-cost constants.

    Attributes:
      peak_flops: dense matmul peak (bf16 for TPU profiles).
      hbm_bw: main-memory bandwidth in bytes/s.
      ici_bw: interconnect bandwidth in bytes/s per link.
      mem_bytes: main-memory capacity.
      dispatch_s: fixed per-jitted-call overhead (host dispatch + launch).
      op_overhead_s: per fused-op overhead inside one call — the term
        that makes multi-stage sparse pipelines lose to one dense matmul
        at short kv_len.
      pallas_native: Pallas kernels compile natively; when False they run
        in interpret mode and predictions scale by ``interpret_slowdown``
        so auto-selection can never cost-pick an interpreted kernel.
    """

    name: str
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    mem_bytes: float
    dispatch_s: float = 5e-6
    op_overhead_s: float = 1e-6
    pallas_native: bool = False
    interpret_slowdown: float = 1.0


#: TPU v5e, per chip (the numbers analysis.py always used).
TPU_V5E = HardwareProfile(
    name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    mem_bytes=16 * 2 ** 30, dispatch_s=5e-6, op_overhead_s=2e-7,
    pallas_native=True, interpret_slowdown=1.0)

#: Conservative CPU host envelope (CI runners, dev machines): XLA:CPU
#: matmul throughput and DRAM bandwidth, with Pallas in interpret mode.
#: Absolute numbers are order-of-magnitude — the autotuner compares
#: backends under ONE profile, so ranking needs the ratios right
#: (sparsity x kv_len vs per-op overhead), not the absolutes.
HOST_CPU = HardwareProfile(
    name="host_cpu", peak_flops=5e10, hbm_bw=2.5e10, ici_bw=1e9,
    mem_bytes=8 * 2 ** 30, dispatch_s=2e-5, op_overhead_s=2e-6,
    pallas_native=False, interpret_slowdown=500.0)

PROFILES = {p.name: p for p in (TPU_V5E, HOST_CPU)}


def get_profile(name: str) -> HardwareProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hardware profile {name!r}; "
                       f"have {sorted(PROFILES)}") from None


def detect_profile() -> HardwareProfile:
    """Profile of the platform JAX actually runs on (TPU else CPU host)."""
    import jax

    return TPU_V5E if jax.default_backend() == "tpu" else HOST_CPU
