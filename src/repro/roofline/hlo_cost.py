"""While-aware HLO cost model (flops / bytes / collective bytes).

``compiled.cost_analysis()`` counts a ``while`` body **once**, regardless
of trip count — for scan-over-layers + grad-accumulation programs that
underestimates flops by ~``n_layers * num_microbatches``.  This module
re-derives the three roofline inputs directly from ``compiled.as_text()``:

* every computation is parsed into instructions (name, shape, op,
  operands, attrs);
* ``while`` ops multiply their body+condition cost by the trip count
  (``backend_config known_trip_count``, else the ``compare(iv, const)``
  constant in the condition computation);
* ``fusion``/``call`` recurse into the called computation for flops,
  while bytes for a fusion are its operands + outputs (internals stay in
  registers) with dynamic-slice / dynamic-update-slice special-cased to
  the *slice* volume — a scanned layer then reads each layer's weights
  once per iteration, which is the physically-correct HBM traffic;
* collectives are summed by kind (operand bytes, trip-aware) — the
  ``collective_t`` roofline numerator.

The parser is validated in tests against ``cost_analysis()`` of the same
program compiled with the scan fully unrolled (tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "token": 0,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "and", "or", "xor", "not",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "clamp", "remainder", "atan2", "is-finite",
}
# transcendentals: count 1 flop/elem too (matches HloCostAnalysis default)
_ELEMENTWISE_1FLOP |= {"exponential", "exponential-minus-one", "log",
                       "log-plus-one", "tanh", "rsqrt", "sqrt", "cbrt",
                       "power", "logistic", "sine", "cosine", "tan",
                       "erf", "real", "imag"}

_ZERO_BYTE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id", "custom-call",  # custom-call handled separately
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")


# --------------------------------------------------------------- parsing
@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str
    args_raw: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s*\(.*\)\s*->.*\{\s*$")
_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*\w*?)\[([\d,]*)\]")


def _find_call_close(s: str, open_idx: int) -> int:
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr(line: str) -> Optional[Instr]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%"):
        return None
    eq = line.find(" = ")
    if eq < 0:
        return None
    name = line[1:eq].strip()
    rest = line[eq + 3:]
    # type is either a (tuple ...) or a single token
    if rest.startswith("("):
        close = _find_call_close(rest, 0)
        type_str = rest[: close + 1]
        rest = rest[close + 1:].lstrip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        rest = rest[sp + 1:]
    m = re.match(r"([\w\-$]+)\(", rest)
    if not m:
        return None
    op = m.group(1)
    close = _find_call_close(rest, m.end() - 1)
    arg_str = rest[m.end(): close]
    attrs = rest[close + 1:]
    operands = re.findall(r"%([\w.\-$]+)", arg_str)
    return Instr(name, type_str, op, operands, attrs, arg_str)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    """-> ({name: Computation}, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and ("->" in line):
                cur = Computation(m.group(1), {}, [])
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    if entry is None:  # fall back: last computation
        entry = next(reversed(comps)) if comps else ""
    return comps, entry


# ----------------------------------------------------------- shape helpers
def type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        b = _DTYPE_BYTES.get(m.group(1))
        if b is None:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def type_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _operand_type(comp: Computation, operand: str) -> str:
    ins = comp.instrs.get(operand)
    return ins.type_str if ins is not None else ""


# ------------------------------------------------------------- cost model
@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    # attribution: {label: flops} / {label: bytes} for the breakdowns
    flops_by_label: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_by_label: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_by_label: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.flops_by_label.items():
            self.flops_by_label[k] = self.flops_by_label.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_label.items():
            self.bytes_by_label[k] = self.bytes_by_label.get(k, 0.0) + v * mult
        for k, v in other.coll_by_label.items():
            self.coll_by_label[k] = self.coll_by_label.get(k, 0.0) + v * mult


_TRIP_RE = re.compile(r'known_trip_count\\?":?\s*\{\\?"?n\\?"?\s*:\s*\\?"?(\d+)')


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    # fall back: largest integer constant in the condition computation
    # (the loop bound of the `compare(iv, const)`)
    mc = re.search(r"condition=%?([\w.\-$]+)", ins.attrs)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = []
        for i in cond.instrs.values():
            if i.op == "constant":
                mm = re.fullmatch(r"-?\d+", i.args_raw.strip())
                if mm:
                    consts.append(int(mm.group(0)))
        if consts:
            return max(consts)
    return 1


def _called(ins: Instr, key: str = "calls") -> List[str]:
    m = re.search(key + r"=%?([\w.\-$]+)", ins.attrs)
    if m:
        return [m.group(1)]
    m = re.search(key + r"=\{([^}]*)\}", ins.attrs)
    if m:
        return re.findall(r"%?([\w.\-$]+)", m.group(1))
    return []


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out = _dims(ins.type_str)
    lhs_t = _operand_type(comp, ins.operands[0]) if ins.operands else ""
    lhs = _dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if m and lhs:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs[int(d)]
    return 2.0 * math.prod(out) * contract if out else 0.0


def _conv_flops(comp: Computation, ins: Instr) -> float:
    # approximation: 2 * out_elems * prod(kernel spatial) * in_feat/groups
    out = math.prod(_dims(ins.type_str)) if _dims(ins.type_str) else 0
    rhs_t = _operand_type(comp, ins.operands[1]) if len(ins.operands) > 1 else ""
    rhs = _dims(rhs_t)
    groups = 1
    m = re.search(r"feature_group_count=(\d+)", ins.attrs)
    if m:
        groups = int(m.group(1))
    k = math.prod(rhs) / max(groups, 1) if rhs else 1
    return 2.0 * out * k / max(rhs[-1] if rhs else 1, 1)


def _label(ins: Instr) -> str:
    m = re.search(r'op_name="([^"]*)"', ins.attrs)
    if not m:
        return ins.op
    # strip jit wrapper + indices for stable grouping
    name = m.group(1)
    name = re.sub(r"\[[^\]]*\]", "", name)
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else ins.op


_PASSTHROUGH = {"bitcast", "copy", "reshape", "transpose",
                "convert", "get-tuple-element"}


def _resolve_param(callee: Computation, name: Optional[str]) -> Optional[str]:
    """Follow no-op chains (bitcast/reshape/...) back to a parameter."""
    seen = 0
    while name is not None and seen < 16:
        ins = callee.instrs.get(name)
        if ins is None:
            return None
        if ins.op == "parameter":
            return name
        if ins.op in _PASSTHROUGH and ins.operands:
            name, seen = ins.operands[0], seen + 1
            continue
        return None
    return None


def _slice_bytes(callee: Computation) -> Optional[Dict[str, float]]:
    """Per-parameter byte override for fusions containing (dynamic-)slice:
    a slice reads only the slice volume of its big operand (a scanned
    layer reads one layer's weights per iteration, not the whole stack)."""
    overrides: Dict[str, float] = {}
    for ins in callee.instrs.values():
        if ins.op in ("dynamic-slice", "slice", "gather"):
            src = _resolve_param(callee, ins.operands[0] if ins.operands
                                 else None)
            if src is not None:
                b = float(type_bytes(ins.type_str))
                overrides[src] = overrides.get(src, 0.0) + b
        if ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd_t = _operand_type(callee, ins.operands[1])
            if not upd_t:  # update defined through a chain: use its def
                upd_t = ins.type_str
            ub = float(type_bytes(upd_t))
            src = _resolve_param(callee, ins.operands[0])
            if src is not None:
                overrides[src] = overrides.get(src, 0.0) + ub
            overrides["__output__"] = ub
    return overrides or None


class HloCost:
    """Trip-count-aware cost walker over a parsed HLO module."""

    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ---- per-computation ----
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[name] = total
            return total
        self._memo[name] = total  # guard (recursion)
        for iname in comp.order:
            total.add(self.instr_cost(comp, comp.instrs[iname]))
        return total

    def _fusion_cost(self, comp: Computation, ins: Instr) -> Cost:
        c = Cost()
        callees = _called(ins)
        # flops: walk fused computation (internals execute)
        inner = Cost()
        overrides = None
        for cal in callees:
            inner.add(self._flops_only(cal))
            ov = _slice_bytes(self.comps[cal]) if cal in self.comps else None
            if ov:
                overrides = ov
        c.flops += inner.flops
        # bytes: fusion operands + output, slice-aware
        callee = self.comps.get(callees[0]) if callees else None
        b = 0.0
        for pos, opd in enumerate(ins.operands):
            t = _operand_type(comp, opd)
            ob = float(type_bytes(t))
            if overrides and callee is not None:
                # match positional parameter name "param_<pos>*"
                for pname, bb in overrides.items():
                    if pname.startswith("param_") and \
                            re.match(rf"param_{pos}(\.|$)", pname):
                        ob = bb
                        break
            b += ob
        out_b = float(type_bytes(ins.type_str))
        if overrides and "__output__" in overrides:
            out_b = overrides["__output__"]
        c.bytes += b + out_b
        lbl = _label(ins)
        c.flops_by_label[lbl] = c.flops
        c.bytes_by_label[lbl] = c.bytes
        return c

    def _flops_only(self, name: str) -> Cost:
        comp = self.comps.get(name)
        c = Cost()
        if comp is None:
            return c
        for iname in comp.order:
            ins = comp.instrs[iname]
            if ins.op == "dot":
                c.flops += _dot_flops(comp, ins)
            elif ins.op == "convolution":
                c.flops += _conv_flops(comp, ins)
            elif ins.op in _ELEMENTWISE_1FLOP:
                c.flops += type_elems(ins.type_str)
            elif ins.op in ("reduce", "reduce-window"):
                c.flops += type_elems(_operand_type(comp, ins.operands[0])) \
                    if ins.operands else 0
            elif ins.op == "fusion" or ins.op == "call":
                for cal in _called(ins):
                    c.add(self._flops_only(cal))
        return c

    # ---- per-instruction ----
    def instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        op = ins.op
        c = Cost()
        if op.endswith("-done") or op == "copy-done":
            return c
        base = op[:-6] if op.endswith("-start") else op

        if base in _COLLECTIVES:
            ob = sum(float(type_bytes(_operand_type(comp, o)))
                     for o in ins.operands)
            # fall back to output size when operands unresolvable
            if ob == 0.0:
                ob = float(type_bytes(ins.type_str))
            c.coll_bytes += ob
            c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + ob
            c.bytes += ob + float(type_bytes(ins.type_str))
            lbl = _label(ins)
            c.bytes_by_label[lbl] = c.bytes
            c.coll_by_label[f"{base}:{lbl}"] = ob
            return c

        if op == "while":
            body = _called(ins, "body")
            cond = _called(ins, "condition")
            trip = _trip_count(ins, self.comps)
            inner = Cost()
            for b in body:
                inner.add(self.comp_cost(b))
            for cd in cond:
                inner.add(self.comp_cost(cd))
            c.add(inner, mult=float(trip))
            return c

        if op == "conditional":
            branches = _called(ins, "branch_computations") or \
                _called(ins, "true_computation") + _called(ins, "false_computation")
            costs = [self.comp_cost(b) for b in branches if b in self.comps]
            if costs:  # max over branches (one executes)
                c.add(max(costs, key=lambda x: x.flops + x.bytes))
            return c

        if op == "fusion":
            return self._fusion_cost(comp, ins)
        if op == "call":
            for cal in _called(ins, "to_apply") or _called(ins):
                c.add(self.comp_cost(cal))
            return c

        lbl = _label(ins)
        if op == "dot":
            c.flops += _dot_flops(comp, ins)
            c.flops_by_label[lbl] = c.flops
        elif op == "convolution":
            c.flops += _conv_flops(comp, ins)
            c.flops_by_label[lbl] = c.flops
        elif op in _ELEMENTWISE_1FLOP:
            c.flops += type_elems(ins.type_str)
        elif op in ("reduce", "reduce-window"):
            c.flops += (type_elems(_operand_type(comp, ins.operands[0]))
                        if ins.operands else 0)

        if op in _ZERO_BYTE_OPS:
            if op == "custom-call":
                c.bytes += float(type_bytes(ins.type_str))
            return c
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2.0 * float(type_bytes(ins.type_str))
        elif op == "dynamic-update-slice":
            upd = (float(type_bytes(_operand_type(comp, ins.operands[1])))
                   if len(ins.operands) > 1 else 0.0)
            c.bytes += 2.0 * upd
        else:
            c.bytes += float(type_bytes(ins.type_str)) + sum(
                float(type_bytes(_operand_type(comp, o)))
                for o in ins.operands)
        c.bytes_by_label[lbl] = c.bytes_by_label.get(lbl, 0.0) + c.bytes
        return c

    # ---- public ----
    def total(self) -> Cost:
        return self.comp_cost(self.entry)


def module_cost(hlo_text: str) -> Cost:
    return HloCost(hlo_text).total()


def top_contributors(cost: Cost, n: int = 12) -> Dict[str, List]:
    fl = sorted(cost.flops_by_label.items(), key=lambda kv: -kv[1])[:n]
    by = sorted(cost.bytes_by_label.items(), key=lambda kv: -kv[1])[:n]
    return {"flops": fl, "bytes": by}
