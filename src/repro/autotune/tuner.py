"""Cost-driven attention-backend chooser with a measured-fallback cache.

:class:`Tuner` is consulted by ``repro.attention.resolve_backend`` when
the effective selection policy is ``"cost"``: for every distinct
:class:`~repro.autotune.cost.CallSig` it ranks the supporting backends by
predicted step time (:func:`repro.autotune.cost.predict`, under the
detected :class:`~repro.roofline.hardware.HardwareProfile` and the
measured sparsity EMA) and returns the winner.

Selection happens at **trace time** — ``attention()`` runs inside jitted
model code where tensors are tracers, so the choice is burnt into the
compiled program and costs nothing per step. Two consequences:

* A close call (top-2 within ``margin``) cannot be timed inline. It is
  recorded as a *pending probe*; :meth:`flush_probes` — called host-side
  by the engine between steps and on scheduler slot recycls — times the
  two candidates once on synthetic inputs of the same signature and
  remembers the winner in the measured cache. A flipped decision bumps
  the engine's attention epoch (a static jit argument), forcing exactly
  one re-trace that re-consults the tuner.
* ``hits``/``misses`` count trace-time consultations, not decode steps.

The measured cache is serializable (:meth:`save`/:meth:`load`, JSON
keyed on ``CallSig.key()``) so serve runs warm-start: a loaded cache
answers every previously-probed signature without re-timing.
``REPRO_TUNER_CACHE`` names a warm-start path for the process-default
tuner.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

from repro.autotune.cost import (CallSig, CostEstimate, SparsityEstimate,
                                 predict)
from repro.roofline.hardware import (HardwareProfile, detect_profile,
                                     get_profile)

#: env var naming a JSON warm-start cache for the process-default tuner.
TUNER_CACHE_ENV = "REPRO_TUNER_CACHE"

_CACHE_VERSION = 1


class Tuner:
    """Per-signature backend chooser: predict, probe on ambiguity, remember.

    Parameters
    ----------
    hw: hardware profile for predictions (default: detect the platform).
    margin: relative predicted-time band treated as ambiguous — first
        sighting of such a signature schedules a one-time probe of the
        top-2 candidates.
    probe_reps: timed repetitions per probed candidate (min is taken;
        one untimed warmup call compiles first).
    cache_path: JSON measured-cache to warm-start from (best effort —
        a missing or unreadable file starts cold).
    """

    def __init__(self, hw: Optional[HardwareProfile] = None, *,
                 margin: float = 0.25, probe_reps: int = 3,
                 cache_path: Optional[str] = None):
        self.hw = hw if hw is not None else detect_profile()
        self.margin = float(margin)
        self.probe_reps = int(probe_reps)
        #: probed ground truth: sig key -> winning backend name
        self.measured: Dict[str, str] = {}
        #: current choice per sig key (measured if present, else predicted)
        self.decision: Dict[str, str] = {}
        #: predicted CostEstimate per candidate per sig key
        self.estimates: Dict[str, Dict[str, CostEstimate]] = {}
        self.sig_by_key: Dict[str, CallSig] = {}
        #: ambiguous first sightings awaiting a host-side probe:
        #: key -> (AttnCall, CallSig, top-2 backend names)
        self.pending: Dict[str, Tuple] = {}
        self.hits = 0
        self.misses = 0
        self.probes = 0
        self._sparsity: Optional[SparsityEstimate] = None
        if cache_path:
            self.load(cache_path)

    # ------------------------------------------------------------ sparsity
    def observe_sparsity(self, block: float, head: float, page: float,
                         beta: float = 0.8) -> None:
        """Fold one engine stats sample into the sparsity EMA."""
        new = SparsityEstimate(block, head, page).clamped()
        old = self._sparsity
        if old is None:
            self._sparsity = new
        else:
            mix = lambda a, b: beta * a + (1 - beta) * b  # noqa: E731
            self._sparsity = SparsityEstimate(
                mix(old.block, new.block), mix(old.head, new.head),
                mix(old.page, new.page))

    def sparsity_for(self, sig: CallSig) -> SparsityEstimate:
        if not sig.hdp:
            return SparsityEstimate()
        return self._sparsity if self._sparsity is not None \
            else SparsityEstimate.prior(sig)

    # -------------------------------------------------------------- choose
    def choose(self, call, sig: CallSig, cands: List):
        """Pick the backend serving ``call`` among ``cands`` (trace time).

        Returns a registry ``Backend``. Measured winners take precedence;
        otherwise the predicted-fastest candidate wins and an ambiguous
        first sighting is queued for a one-time probe.
        """
        key = sig.key()
        self.sig_by_key[key] = sig
        by_name = {b.name: b for b in cands}
        sp = self.sparsity_for(sig)
        ests = {b.name: predict(b.name, sig, self.hw, sp) for b in cands}
        self.estimates[key] = ests
        meas = self.measured.get(key)
        if meas is not None and meas in by_name:
            self.hits += 1
            self.decision[key] = meas
            return by_name[meas]
        self.misses += 1
        ranked = sorted(cands,
                        key=lambda b: (ests[b.name].step_time(self.hw),
                                       b.name))
        best = ranked[0]
        if len(ranked) > 1 and key not in self.pending:
            t1 = ests[ranked[0].name].step_time(self.hw)
            t2 = ests[ranked[1].name].step_time(self.hw)
            if t2 <= t1 * (1.0 + self.margin):
                self.pending[key] = (call, sig, (ranked[0].name,
                                                 ranked[1].name))
        self.decision[key] = best.name
        return best

    # -------------------------------------------------------------- probes
    def flush_probes(self) -> bool:
        """Run every pending probe (host side, synthetic inputs).

        Returns True when any measured winner differs from the standing
        predicted decision — the caller's cue to bump its attention
        epoch so the next trace re-consults the tuner.
        """
        if not self.pending:
            return False
        changed = False
        for key, (call, sig, names) in list(self.pending.items()):
            try:
                winner = self._probe(call, sig, names)
            except Exception:
                # a probe failure must never take serving down; keep the
                # predicted decision and stop re-trying this signature
                del self.pending[key]
                continue
            del self.pending[key]
            self.measured[key] = winner
            self.probes += 1
            if self.decision.get(key) != winner:
                self.decision[key] = winner
                changed = True
        return changed

    def _probe(self, call, sig: CallSig, names) -> str:
        """Time each candidate once on synthetic inputs; fastest wins."""
        import jax

        from repro.attention.registry import get_backend

        args = _synthetic_inputs(call, sig)
        best_name, best_t = None, None
        for name in names:
            backend = get_backend(name)
            fn = jax.jit(lambda q, k, v, cache, table, qp, kp,
                         _b=backend: _b.run(q, k, v, call, q_pos=qp,
                                            k_pos=kp, cache=cache,
                                            page_table=table)[0])
            out = fn(*args)          # compile + warm
            out.block_until_ready()
            t_min = None
            for _ in range(self.probe_reps):
                t0 = time.perf_counter()
                fn(*args).block_until_ready()
                dt = time.perf_counter() - t0
                t_min = dt if t_min is None else min(t_min, dt)
            if best_t is None or t_min < best_t:
                best_name, best_t = name, t_min
        return best_name

    # ------------------------------------------------------------ reporting
    def decision_for(self, call) -> Optional[str]:
        """Standing decision whose signature matches ``call``'s phase
        (mode / layout / draft / verify), or None before any trace."""
        want = (call.mode, call.layout, call.draft is not None, call.verify)
        for key in reversed(list(self.decision)):
            sig = self.sig_by_key.get(key)
            if sig is None:
                continue
            if (sig.mode, sig.layout, sig.draft != "", sig.verify) == want:
                return self.decision[key]
        return None

    def estimate_for(self, call) -> Optional[Tuple[str, CostEstimate]]:
        """(chosen backend, its CostEstimate) for ``call``'s phase."""
        want = (call.mode, call.layout, call.draft is not None, call.verify)
        for key in reversed(list(self.decision)):
            sig = self.sig_by_key.get(key)
            if sig is None:
                continue
            if (sig.mode, sig.layout, sig.draft != "", sig.verify) == want:
                name = self.decision[key]
                est = self.estimates.get(key, {}).get(name)
                if est is not None:
                    return name, est
        return None

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "probes": self.probes, "pending": len(self.pending),
                "measured": len(self.measured)}

    # -------------------------------------------------------- serialization
    def save(self, path: str) -> None:
        data = {"version": _CACHE_VERSION, "hw": self.hw.name,
                "measured": dict(self.measured)}
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")

    def load(self, path: str) -> bool:
        """Merge a saved measured cache (same hardware profile only)."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return False
        if data.get("version") != _CACHE_VERSION \
                or data.get("hw") != self.hw.name:
            return False
        self.measured.update(data.get("measured") or {})
        return True


def _synthetic_inputs(call, sig: CallSig):
    """(q, k, v, cache, table, q_pos, k_pos) matching ``sig``'s shapes.

    Mirrors the serving layout contracts: paged pools are the per-call
    [P, ps, N, hd] views with page 0 as scratch and tables pointing at
    pages 1..; per-slot position arrays carry the batch dim with -1
    marking invalid columns.
    """
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(0)
    B, N, G, Sq, hd = (sig.batch, sig.n_kv_heads, sig.group, sig.sq, sig.hd)
    kv = sig.kv_len
    q = jnp.asarray(rng.standard_normal((B, N, G, Sq, hd)), jnp.float32)
    k_host = rng.standard_normal((B, kv, N, hd)).astype(np.float32)
    v_host = rng.standard_normal((B, kv, N, hd)).astype(np.float32)
    last = kv - 1
    pos = jnp.arange(kv - Sq, kv, dtype=jnp.int32)[None, :].repeat(B, 0)
    ar = jnp.arange(kv, dtype=jnp.int32)
    if sig.per_slot:
        q_pos = pos[:, None, None, :]
        k_pos = jnp.where(ar[None, :] <= last, ar[None, :], -1)
        k_pos = k_pos[:, None, None, :].repeat(B, 0)
    else:
        q_pos = pos[0]
        k_pos = ar

    if call.layout != "paged":
        return (q, jnp.asarray(k_host), jnp.asarray(v_host), None, None,
                q_pos, k_pos)

    from repro.models.attention import scout_frac_int8, scout_int8

    ps = sig.page_size
    n_pages = kv // ps
    P = B * n_pages + 1                     # + scratch page 0
    k_pages = np.zeros((P, ps, N, hd), np.float32)
    v_pages = np.zeros((P, ps, N, hd), np.float32)
    k_pages[1:] = k_host.reshape(B * n_pages, ps, N, hd)
    v_pages[1:] = v_host.reshape(B * n_pages, ps, N, hd)
    cache = {"k_pages": jnp.asarray(k_pages),
             "v_pages": jnp.asarray(v_pages)}
    if call.hdp is not None:
        scout = scout_int8(jnp.asarray(k_host), call.hdp)
        sc = np.zeros((P, ps, N, hd), np.int8)
        sc[1:] = np.asarray(scout).reshape(B * n_pages, ps, N, hd)
        cache["k_scout"] = jnp.asarray(sc)
        if call.draft is not None and call.draft.scores == "scout":
            frac = scout_frac_int8(jnp.asarray(k_host), call.hdp)
            fc = np.zeros((P, ps, N, hd), np.int8)
            fc[1:] = np.asarray(frac).reshape(B * n_pages, ps, N, hd)
            cache["f_scout"] = jnp.asarray(fc)
    table = jnp.arange(1, B * n_pages + 1,
                       dtype=jnp.int32).reshape(B, n_pages)
    return q, None, None, cache, table, q_pos, k_pos


# ------------------------------------------------------- process default
_DEFAULT: Optional[Tuner] = None


def default_tuner() -> Tuner:
    """The process-wide tuner cost-policy dispatch consults (lazy).

    Honors ``REPRO_TUNER_CACHE`` for warm-start. Engines running under
    ``policy="cost"`` share it — measured winners and the sparsity EMA
    carry across engines in one process, which is the warm-start
    semantics the serve benches rely on.
    """
    global _DEFAULT
    if _DEFAULT is None:
        path = os.environ.get(TUNER_CACHE_ENV, "").strip() or None
        _DEFAULT = Tuner(cache_path=path)
    return _DEFAULT


def set_default_tuner(tuner: Optional[Tuner]) -> None:
    global _DEFAULT
    _DEFAULT = tuner


def reset_default_tuner() -> None:
    set_default_tuner(None)


def get_profile_by_name(name: str) -> HardwareProfile:
    return get_profile(name)
