"""Cost-driven attention autotuning + adaptive speculation.

Three cooperating pieces, wired into serving when
``AttnSpec(policy="cost")`` (or ``REPRO_ATTN_POLICY=cost``) is active:

* :mod:`repro.autotune.cost` — analytic bytes/FLOPs/step-time predictor
  per registered attention backend, parameterized by the call signature
  and the engine's measured sparsity counters.
* :mod:`repro.autotune.tuner` — per-signature backend chooser with a
  measured-fallback probe cache (serializable for warm starts).
* :mod:`repro.autotune.speculation` — acceptance-EMA controller setting
  the speculative draft length and draft prune aggressiveness per round.
"""
from repro.autotune.cost import (OP_WEIGHT, CallSig, CostEstimate,
                                 SparsityEstimate, call_signature,
                                 crossover_table, predict,
                                 predict_engine_step)
from repro.autotune.speculation import SpecConfig, SpecController
from repro.autotune.tuner import (TUNER_CACHE_ENV, Tuner, default_tuner,
                                  reset_default_tuner, set_default_tuner)

__all__ = [
    "CallSig", "CostEstimate", "SparsityEstimate", "OP_WEIGHT",
    "call_signature", "predict", "predict_engine_step", "crossover_table",
    "Tuner", "TUNER_CACHE_ENV", "default_tuner", "set_default_tuner",
    "reset_default_tuner", "SpecConfig", "SpecController",
]
