"""Analytic bytes/FLOPs/step-time predictor per attention backend.

One :class:`CallSig` captures the static *shape* of an attention
invocation — what :class:`repro.attention.AttnCall` deliberately omits so
it stays a pure capability descriptor: batch, head geometry, query span,
KV extent, page geometry and dtypes. The signature is built at trace
time (shapes and dtypes are static under ``jax.jit``) by
:func:`call_signature` and is the tuner's cache key.

:func:`predict` maps ``(backend name, CallSig, HardwareProfile,
SparsityEstimate)`` to a :class:`CostEstimate` — HBM bytes + FLOPs for
the attention call, plus a per-backend fixed overhead term modelling the
extra fused ops a multi-stage sparse pipeline dispatches. Step time is
the roofline max of the compute and memory terms plus the overhead;
Pallas backends on a non-native host are scaled by the profile's
interpret-mode slowdown so cost selection can never pick an interpreted
kernel.

The formulas model what the backends actually stream:

* ``xla_dense`` — Q/O traffic + the full K/V extent once, dense QK/PV.
* ``xla_hdp`` — dense layout: the scout is (re)quantized from full K per
  call and every byte is streamed regardless of the masks (pruning only
  saves *compute* there), so HDP costs MORE than dense at equal shapes.
* ``paged_hdp_decode`` / ``pallas_*`` paged — int8 scout bytes over the
  resident extent + only the *surviving* fraction of full-precision
  K/V (fetch-upon-mask); draft calls with scout scores never read
  full K at all. This is the term the measured page-sparsity counters
  sharpen: benefit grows with ``sparsity x kv_len``, overhead does not.
* ``reference`` — the densifying oracle: materializes gathered K/V and
  [Sq, Sk] masks; priced accordingly so it is never cost-picked.

Cross-checked against the while-aware HLO cost model
(`roofline/hlo_cost.py`) on compiled backend jits in
tests/test_autotune.py — absolute FLOPs within a small factor, kv_len
*scaling* tight.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.roofline.hardware import HardwareProfile

#: per-backend fused-op weight: roughly how many extra kernel launches /
#: fusion barriers the implementation costs beyond one dense matmul pair.
#: Multiplies ``HardwareProfile.op_overhead_s`` — the constant term that
#: makes sparse pipelines lose below the sparsity x kv_len crossover.
OP_WEIGHT = {
    "xla_dense": 2.0,
    "xla_hdp": 8.0,
    "paged_hdp_decode": 14.0,
    "pallas_flash": 1.0,
    "pallas_hdp_block": 6.0,
    "pallas_paged_decode": 4.0,
    "reference": 24.0,
}

_PALLAS = ("pallas_flash", "pallas_hdp_block", "pallas_paged_decode")


@dataclasses.dataclass(frozen=True)
class CallSig:
    """Static shape signature of one attention invocation (hashable)."""

    mode: str               # "prefill" | "decode"
    layout: str             # "dense" | "paged"
    batch: int
    n_kv_heads: int
    group: int              # query heads per KV head (GQA)
    sq: int                 # query span (verify calls: draft_len)
    hd: int
    kv_len: int             # visible KV extent (paged: pages_per_slot*ps)
    page_size: int = 0      # 0 for dense layout
    q_itemsize: int = 4
    kv_itemsize: int = 4
    hdp: bool = False
    block_q: int = 0
    block_k: int = 0
    draft: str = ""         # DraftProfile.scores, "" = full-fidelity
    verify: bool = False
    causal: bool = True
    window: int = 0
    per_slot: bool = False
    tp: int = 1             # tensor-parallel degree (shapes are per-shard)

    @property
    def heads(self) -> int:
        return self.n_kv_heads * self.group

    def key(self) -> str:
        """Serializable tuner-cache key (stable across processes)."""
        return (f"{self.mode}:{self.layout}:b{self.batch}:n{self.n_kv_heads}"
                f"xg{self.group}:sq{self.sq}:hd{self.hd}:kv{self.kv_len}"
                f":ps{self.page_size}:dt{self.q_itemsize}.{self.kv_itemsize}"
                f":hdp{int(self.hdp)}:bq{self.block_q}:bk{self.block_k}"
                f":dr{self.draft or '-'}:v{int(self.verify)}"
                f":c{int(self.causal)}:w{self.window}:s{int(self.per_slot)}"
                f":tp{self.tp}")


def call_signature(call, q, k=None, cache=None, page_table=None,
                   tp: int = 1) -> CallSig:
    """Build the CallSig for a live dispatch (trace-safe: shapes/dtypes).

    ``q`` is the [B,N,G,Sq,hd] query; paged calls derive the KV extent
    from the page pool + table, dense calls from ``k``. Under
    tensor-parallel serving the dispatch runs inside shard_map, so the
    shapes (and hence every byte/FLOP term) are already per-shard —
    ``tp`` records the mesh degree so probe caches never mix mesh
    shapes and the predictor can price the output all-gather.
    """
    B, N, G, Sq, hd = q.shape
    if call.layout == "paged":
        ps = cache["k_pages"].shape[1]
        kv = page_table.shape[1] * ps
        kv_item = cache["k_pages"].dtype.itemsize
    else:
        ps = 0
        kv = k.shape[1] if k is not None else Sq
        kv_item = k.dtype.itemsize if k is not None else q.dtype.itemsize
    hdp = call.hdp
    return CallSig(
        mode=call.mode, layout=call.layout, batch=B, n_kv_heads=N, group=G,
        sq=Sq, hd=hd, kv_len=kv, page_size=ps,
        q_itemsize=q.dtype.itemsize, kv_itemsize=kv_item,
        hdp=hdp is not None,
        block_q=hdp.block_q if hdp is not None else 0,
        block_k=hdp.block_k if hdp is not None else 0,
        draft=call.draft.scores if call.draft is not None else "",
        verify=call.verify, causal=call.causal, window=call.window,
        per_slot=call.per_slot, tp=max(int(tp), 1))


@dataclasses.dataclass(frozen=True)
class SparsityEstimate:
    """Surviving-work fractions the predictor scales sparse terms by.

    Fed from the engine's measured AttnStats means (block / head / page
    sparsity EMAs); the prior before any measurement is derived from the
    HDP thresholds — deliberately conservative (rho_b only suggests, the
    data decides), so unmeasured predictions under-promise HDP.
    """

    block: float = 0.0
    head: float = 0.0
    page: float = 0.0

    @classmethod
    def prior(cls, sig: CallSig) -> "SparsityEstimate":
        if not sig.hdp:
            return cls()
        # a positive survival threshold prunes roughly the mass below it;
        # claim half of that until the counters say otherwise
        return cls(block=0.25, head=0.0, page=0.25)

    def clamped(self) -> "SparsityEstimate":
        f = lambda x: min(max(float(x), 0.0), 0.999)  # noqa: E731
        return SparsityEstimate(f(self.block), f(self.head), f(self.page))


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Attention-call cost: roofline terms + fixed pipeline overhead."""

    flops: float
    hbm_bytes: float
    overhead_s: float
    interpreted: bool = False

    def step_time(self, hw: HardwareProfile) -> float:
        t = (max(self.flops / hw.peak_flops, self.hbm_bytes / hw.hbm_bw)
             + self.overhead_s)
        return t * hw.interpret_slowdown if self.interpreted else t


def predict(backend: str, sig: CallSig, hw: HardwareProfile,
            sparsity: Optional[SparsityEstimate] = None) -> CostEstimate:
    """CostEstimate of ``backend`` serving one call shaped ``sig``."""
    sp = (sparsity if sparsity is not None
          else SparsityEstimate.prior(sig)).clamped()
    B, H, N = sig.batch, sig.heads, sig.n_kv_heads
    Sq, kv, hd = sig.sq, sig.kv_len, sig.hd
    if sig.causal and sig.mode == "prefill" and Sq == kv:
        kv_eff = max(kv / 2.0, 1.0)      # triangular extent actually scored
    else:
        kv_eff = float(kv)

    q_io = 2.0 * B * H * Sq * hd * sig.q_itemsize        # read Q + write O
    kv_full = 2.0 * B * kv * N * hd * sig.kv_itemsize    # K + V, whole extent
    scout_io = 1.0 * B * kv * N * hd                     # int8 scout copy
    dot = 4.0 * B * H * Sq * kv_eff * hd                 # QK^T + PV
    softmax = 8.0 * B * H * Sq * kv_eff

    surv_b = 1.0 - max(sp.block, sp.page)   # surviving KV fraction
    surv_h = 1.0 - sp.head                  # surviving head fraction
    ov = hw.op_overhead_s * OP_WEIGHT.get(backend, 8.0)

    if backend == "xla_dense":
        f, by = dot + softmax, q_io + kv_full
    elif backend in ("xla_hdp", "pallas_hdp_block") and sig.layout == "dense":
        # dense HDP: full K/V streamed regardless of masks, K read twice
        # (quantize pass + attention); scout matmul on top of the dense
        # pair — pruning saves compute only, never bytes
        f = (dot + softmax) * surv_b * surv_h + 2.0 * B * H * Sq * kv_eff * hd
        by = q_io + kv_full * 1.5
    elif backend in ("paged_hdp_decode", "pallas_hdp_block",
                     "pallas_paged_decode"):
        # fetch-upon-mask: scout streamed over the resident extent, full
        # K/V only for surviving pages/blocks of surviving heads
        f = (2.0 * B * H * Sq * kv_eff * hd            # int scout scoring
             + (dot + softmax) * surv_b * surv_h)
        scout = scout_io * (2.0 if sig.draft == "scout" else 1.0)
        if sig.draft in ("scout", "int"):
            # draft steps never touch full-precision K; V of surviving
            # pages is still gathered for the weighted sum
            by = q_io + scout + surv_b * kv_full / 2.0
        else:
            by = q_io + scout + surv_b * kv_full * surv_h
    elif backend == "pallas_flash":
        f, by = dot + softmax, q_io + kv_full
    elif backend == "reference":
        # materializing oracle: densified gather + [Sq, Sk] score/mask
        # tensors as real arrays, everything re-read per stage
        f = 3.0 * dot + 4.0 * softmax
        by = q_io + 4.0 * kv_full + 4.0 * B * H * Sq * kv * sig.q_itemsize
    else:
        # unknown backend: dense-equivalent with a hefty uncertainty tax
        f, by, ov = dot + softmax, q_io + kv_full, ov * 4.0

    if sig.tp > 1:
        # tensor-parallel serving: each shard all-gathers the other
        # shards' per-head output slices before the o-projection. The
        # sig's shapes are per-shard, so H is the LOCAL head count; the
        # gathered traffic is the (tp-1) remote slices of the global
        # [B, H*tp, Sq, hd] output
        by = by + 2.0 * B * (H * sig.tp) * Sq * hd * sig.q_itemsize \
            * (sig.tp - 1) / sig.tp

    return CostEstimate(flops=f, hbm_bytes=by, overhead_s=ov,
                        interpreted=(backend in _PALLAS
                                     and not hw.pallas_native))


def predict_engine_step(n_active_params: int, batch: int, n_layers: int,
                        attn_est: CostEstimate, hw: HardwareProfile,
                        param_itemsize: int = 4) -> float:
    """Predicted wall time of one fused decode step of a whole model.

    Model term: 2*N_active FLOPs per token vs one full weight read
    (single-token decode is weight-bandwidth-bound); attention term: the
    per-layer call estimate times the layer count, plus one dispatch.
    """
    model_t = max(2.0 * n_active_params * batch / hw.peak_flops,
                  n_active_params * param_itemsize / hw.hbm_bw)
    return model_t + n_layers * attn_est.step_time(hw) + hw.dispatch_s


def crossover_table(sig: CallSig, hw: HardwareProfile, kv_lens,
                    page_sparsities) -> list:
    """kv_len x sparsity grid: predicted paged-HDP vs dense step time.

    The motivating tradeoff of the whole subsystem in one table — where
    ``sparsity x kv_len`` beats the sparse pipeline's overhead. The HDP
    side is priced at the *pool's* ``sig.kv_itemsize`` (1 under the
    production int8 store: surviving pages stream codes, dequant never
    round-trips HBM — a ~4x resident-extent byte drop that moves the
    crossover toward HDP at much shorter kv_len x sparsity products),
    while the dense comparator always streams the fp32 request cache.
    Returned rows carry both predicted times, the priced pool itemsize
    and the winner; recorded into BENCH_serving.json by the
    serving_autotune bench.
    """
    rows = []
    for kv in kv_lens:
        for psp in page_sparsities:
            s_hdp = dataclasses.replace(sig, kv_len=int(kv), hdp=True)
            s_dense = dataclasses.replace(sig, kv_len=int(kv), hdp=False,
                                          layout="dense", page_size=0,
                                          kv_itemsize=4)
            t_hdp = predict("paged_hdp_decode", s_hdp, hw,
                            SparsityEstimate(page=psp)).step_time(hw)
            t_dense = predict("xla_dense", s_dense, hw).step_time(hw)
            rows.append({"kv_len": int(kv), "page_sparsity": round(psp, 3),
                         "kv_itemsize": sig.kv_itemsize,
                         "t_hdp_s": t_hdp, "t_dense_s": t_dense,
                         "winner": "hdp" if t_hdp < t_dense else "dense"})
    return rows
