"""Adaptive self-speculative decoding: acceptance-driven draft control.

The engine's speculative round drafts ``k - 1`` tokens with an
approximate attention pass and verifies them with one multi-query exact
pass; exact-match acceptance makes the committed stream byte-identical
to greedy decode *at any draft length and any draft profile* — the two
knobs only move the work/acceptance tradeoff. That makes them safe to
tune online, which is what :class:`SpecController` does (the Energon
idea applied to the HDP draft): keep a running acceptance-rate EMA and,
per round, pick

* ``k`` — the round length (1 draft call proposes ``k - 1`` tokens; at
  ``k = 1`` the round degenerates to one exact decode step, speculation
  effectively off), scaled linearly with the EMA between configured
  bounds; and
* the :class:`~repro.attention.DraftProfile` — prune-threshold overrides
  for the draft pass: when acceptance is high the draft can afford to
  prune *more* aggressively (rho_b / tau_h raised), when acceptance
  collapses the overrides are dropped so the draft matches the exact
  pass's thresholds and acceptance recovers.

Both outputs are static jit arguments in the engine (round length is a
scan bound, the profile is folded into the traced HDP config), so the
controller deliberately quantizes to a *small finite set* of (k,
profile) pairs — at most ``k_max x 3`` traces per engine, each compiled
once and reused.

The ``scores`` field of the profile is never varied: the draft-scout
page pool is allocated at cache-build time based on it, so flipping it
mid-serve would need a cache rebuild, not just a retrace.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.attention.spec import DraftProfile
from repro.core.config import HDPConfig


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Controller knobs (defaults tuned for the serving tests' scale).

    Attributes:
      k_min / k_max: round-length bounds (k tokens committed per accepted
        round; k_min=1 lets the controller switch speculation off).
      beta: EMA retention per round (higher = slower adaptation).
      init_ema: optimistic start — the first rounds draft at full length
        and the measured acceptance walks the EMA down if undeserved.
      aggressive_above / conservative_below: EMA thresholds picking the
        draft profile tier; between them the engine's base profile runs.
      rho_step / tau_step: how far the aggressive tier raises the HDP
        survival thresholds above the base draft overlay.
    """

    k_min: int = 1
    k_max: int = 4
    beta: float = 0.7
    init_ema: float = 1.0
    aggressive_above: float = 0.8
    conservative_below: float = 0.35
    rho_step: float = 0.1
    tau_step: float = 0.05

    def __post_init__(self):
        if not (1 <= self.k_min <= self.k_max):
            raise ValueError(
                f"need 1 <= k_min <= k_max, got ({self.k_min}, {self.k_max})")
        if not (0.0 <= self.beta < 1.0):
            raise ValueError(f"beta must be in [0, 1), got {self.beta}")


class SpecController:
    """Acceptance-EMA draft-length + draft-profile chooser.

    Parameters
    ----------
    base: the engine's configured draft profile (the middle tier).
    hdp: the exact pass's HDP config — the threshold baseline that the
        aggressive tier steps up from when ``base`` has no override.
    cfg: controller knobs.
    """

    def __init__(self, base: DraftProfile, hdp: Optional[HDPConfig] = None,
                 cfg: Optional[SpecConfig] = None):
        self.cfg = cfg if cfg is not None else SpecConfig()
        self.base = base
        self.ema = float(self.cfg.init_ema)
        self.rounds = 0
        self.drafted_total = 0
        self.accepted_total = 0
        self.k_total = 0

        rho0 = base.rho_b if base.rho_b is not None \
            else (hdp.rho_b if hdp is not None else 0.5)
        tau0 = base.tau_h if base.tau_h is not None \
            else (hdp.tau_h if hdp is not None else 0.0)
        self.conservative = DraftProfile(scores=base.scores)
        self.aggressive = DraftProfile(
            rho_b=min(0.95, rho0 + self.cfg.rho_step),
            tau_h=tau0 + self.cfg.tau_step,
            scores=base.scores)

    # ----------------------------------------------------------------- plan
    def plan(self) -> Tuple[int, DraftProfile]:
        """(k, draft profile) for the next round."""
        c = self.cfg
        k = 1 + int(round(self.ema * (c.k_max - 1)))
        k = max(c.k_min, min(c.k_max, k))
        if self.ema >= c.aggressive_above:
            profile = self.aggressive
        elif self.ema < c.conservative_below:
            profile = self.conservative
        else:
            profile = self.base
        self.k_total += k
        return k, profile

    # --------------------------------------------------------------- update
    def update(self, accepted: int, drafted: int) -> None:
        """Fold one round's outcome in.

        ``accepted`` counts accepted *draft* tokens (the verify step's
        guaranteed token is not a speculation win); ``drafted <= 0``
        rounds (k = 1, no draft ran) leave the EMA untouched — no
        evidence either way.
        """
        self.rounds += 1
        if drafted <= 0:
            return
        self.drafted_total += int(drafted)
        self.accepted_total += int(accepted)
        rate = min(max(accepted / drafted, 0.0), 1.0)
        self.ema = self.cfg.beta * self.ema + (1.0 - self.cfg.beta) * rate

    # -------------------------------------------------------------- summary
    def summary(self) -> dict:
        return {
            "acceptance_ema": self.ema,
            "rounds": self.rounds,
            "drafted": self.drafted_total,
            "accepted": self.accepted_total,
            "acceptance_rate": (self.accepted_total / self.drafted_total
                                if self.drafted_total else None),
            "draft_len_mean": (self.k_total / self.rounds
                               if self.rounds else None),
        }
