"""Serving launcher: batched requests through the HDP engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 16 --max-new 8

Drives `serving.Engine` (continuous batching, per-slot positions, HDP
prefill/decode) with synthetic prompts and reports throughput + achieved
HDP sparsity. `--no-hdp` serves the identical model with dense attention
for an A/B of output agreement and step cost. `--stream-sched` (with an
optional seeded `--arrival-rate` Poisson request stream) serves through
the continuous-batching scheduler and additionally reports TTFT / TPOT /
queue-depth stats.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import sys

import numpy as np

from repro.attention import AttnSpec, spec_from_legacy
from repro.configs import get_config
from repro.configs.base import reduced
from repro.serving import Engine, ReplicaSet, Request, SchedulerConfig
from repro.serving.engine import MESH_DP_ENV

log = logging.getLogger("repro.serve")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--no-hdp", action="store_true")
    ap.add_argument("--rho-b", type=float, default=None)
    ap.add_argument("--tau-h", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    help="attention backend name or family tag from the "
                         "repro.attention registry (auto | reference | xla | "
                         "pallas | an exact name like paged_hdp_decode)")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="serving cache layout: paged = block-paged KV cache "
                         "(FUM page gather); dense = per-slot contiguous")
    ap.add_argument("--cache-backend", default=None,
                    choices=["auto", "paged", "dense"],
                    help="DEPRECATED: use --layout")
    ap.add_argument("--attn-backend", default=None,
                    choices=["xla", "pallas"],
                    help="DEPRECATED: use --backend")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "fp32", "int8", "fp8_v"],
                    help="paged KV pool storage format: int8 = per-page "
                         "scaled int8 codes (the default store), fp8_v = "
                         "int8 K + fp8 V, fp32 = the full-precision A/B "
                         "oracle. auto honors REPRO_KV_DTYPE, else int8; "
                         "dense layout always serves fp32")
    ap.add_argument("--tp", type=int, default=None,
                    help="tensor-parallel degree: shard the paged KV pool "
                         "and the decode attention over a jax mesh's "
                         "'model' (head) axis; token-identical to tp=1. "
                         "Needs >= tp devices (on CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N). "
                         "Default honors REPRO_MESH_TP, else 1")
    ap.add_argument("--dp", type=int, default=None,
                    help="data-parallel engine replicas behind one "
                         "dispatching front-end (prefix-affinity then "
                         "least-loaded); replicas share one params tree so "
                         "tokens are dispatch-invariant. Default honors "
                         "REPRO_MESH_DP, else 1")
    ap.add_argument("--kv-scale", default="grid",
                    choices=["grid", "absmax"],
                    help="int8 KV pool scale calibration: grid = one static "
                         "power-of-two scale (bit-parity with the scout "
                         "grid); absmax = per-page per-kv-head calibrated "
                         "scales (lower round-trip error, drift-gated "
                         "rather than bit-exact vs the scout)")
    ap.add_argument("--calib", default=None,
                    help="override hdp calibration (the paged scout stores "
                         "a write-time int8 copy, i.e. calib-free)")
    ap.add_argument("--decode-horizon", type=int, default=None,
                    help="tokens per fused decode call (jitted lax.scan "
                         "loop): one host sync per horizon instead of per "
                         "token, token-identical to 1; default honors "
                         "REPRO_DECODE_HORIZON, else 1")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="share prompt-prefix pages across requests through "
                         "the refcounted radix tree (paged layout); prefill "
                         "runs only on the unshared suffix. Default honors "
                         "REPRO_PREFIX_CACHE, else off")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="force prefix caching off (the cold A/B leg)")
    ap.add_argument("--spec-decode", dest="spec_decode",
                    action="store_true", default=None,
                    help="self-speculative decode: per round, draft-len-1 "
                         "approximate draft steps (int8-scout attention) "
                         "plus ONE multi-query verify over the serving "
                         "cache; token-identical to plain greedy decode. "
                         "Default honors REPRO_SPEC_DECODE, else off")
    ap.add_argument("--no-spec-decode", dest="spec_decode",
                    action="store_false",
                    help="force speculative decode off (the A/B baseline)")
    ap.add_argument("--draft-len", type=int, default=None,
                    help="tokens proposed+verified per speculative round; "
                         "default honors REPRO_DRAFT_LEN, else 4")
    ap.add_argument("--policy", default=None,
                    choices=["static", "cost"],
                    help="auto-selection policy for the attention backend: "
                         "static = registry priority order; cost = the "
                         "repro.autotune cost model ranks candidates under "
                         "the detected hardware profile (probing ambiguous "
                         "calls once). Default honors REPRO_ATTN_POLICY, "
                         "else static")
    ap.add_argument("--tuner-cache", default=None,
                    help="JSON path for the cost-policy tuner's measured "
                         "cache: loaded before serving (warm start) and "
                         "written back after, so repeat runs skip probes")
    ap.add_argument("--adaptive-spec", dest="adaptive_spec",
                    action="store_true", default=None,
                    help="acceptance-adaptive speculation: an EMA of the "
                         "draft acceptance rate re-plans draft length and "
                         "draft prune aggressiveness per round "
                         "(token-identical at any plan). Default honors "
                         "REPRO_ADAPTIVE_SPEC, else off")
    ap.add_argument("--no-adaptive-spec", dest="adaptive_spec",
                    action="store_false",
                    help="force adaptive speculation off (fixed draft_len)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every synthetic prompt a common random "
                         "prefix of this many tokens (the prefix-cache "
                         "benchmark workload); 0 = fully random prompts")
    ap.add_argument("--stream-sched", dest="stream_sched",
                    action="store_true", default=None,
                    help="continuous-batching stream scheduler: token-"
                         "budget admission, prefix-hit-first ordering, "
                         "mid-run slot recycling, chunked prefill "
                         "interleaved with decode. Token-identical to "
                         "static serving. Default honors "
                         "REPRO_STREAM_SCHED, else off")
    ap.add_argument("--no-stream-sched", dest="stream_sched",
                    action="store_false",
                    help="force the stream scheduler off (the static A/B "
                         "leg)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean request arrivals per engine step (Poisson "
                         "process, seeded): requests are submitted while "
                         "the engine is already decoding, exercising mid-"
                         "run admission. 0 = submit everything up front. "
                         "Needs --stream-sched")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="interleaved-prefill token budget per engine step "
                         "for long prompts under the stream scheduler; "
                         "default = one largest-bucket chunk per step")
    ap.add_argument("--watchdog-steps", type=int, default=500,
                    help="no-progress engine steps with requests pending "
                         "before the stream scheduler's watchdog sheds the "
                         "stalled queue head (raises past its escalation "
                         "threshold)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault-injection schedule "
                         "('kind@step[:k=v,..];...', kinds exhaust | error "
                         "| nan | slow | kill — see repro.serving.faults). "
                         "Steps count engine steps; --warmup pauses "
                         "injection and restarts step numbering afterwards, "
                         "so fault steps always index the measured traffic. "
                         "Default honors REPRO_FAULT_PLAN, else no faults")
    ap.add_argument("--warmup", action="store_true",
                    help="run one throwaway request through the engine and "
                         "reset metrics before serving, so reported tok/s "
                         "is steady-state rather than jit-compile time "
                         "(what the benchmark A/B records)")
    return ap


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.hdp is not None:
        hdp = cfg.hdp
        if args.no_hdp:
            hdp = dataclasses.replace(hdp, enabled=False)
        if args.rho_b is not None:
            hdp = dataclasses.replace(hdp, rho_b=args.rho_b)
        if args.tau_h is not None:
            hdp = dataclasses.replace(hdp, tau_h=args.tau_h)
        if args.calib is not None:
            hdp = dataclasses.replace(hdp, calib=args.calib)
        cfg = cfg.replace(hdp=hdp)

    policy = getattr(args, "policy", None)
    spec = AttnSpec(backend=args.backend, layout=args.layout,
                    policy=policy if policy is not None else "auto",
                    kv_dtype=getattr(args, "kv_dtype", "auto"),
                    kv_scale=getattr(args, "kv_scale", "grid"))
    if args.attn_backend is not None or args.cache_backend is not None:
        # one-release deprecation shim for the old string flags
        spec = spec_from_legacy(args.attn_backend, args.cache_backend,
                                base=spec)
    tuner = None
    tuner_cache = getattr(args, "tuner_cache", None)
    if tuner_cache:
        from repro.autotune import Tuner
        tuner = Tuner(cache_path=tuner_cache)
    stream = getattr(args, "stream_sched", None)
    sched_cfg = SchedulerConfig(
        prefill_chunk_tokens=getattr(args, "prefill_chunk", None),
        watchdog_steps=getattr(args, "watchdog_steps", 500)) \
        if stream else None
    dp = getattr(args, "dp", None)
    if dp is None:
        dp = int(os.environ.get(MESH_DP_ENV) or 1)
    dp = max(int(dp), 1)
    engine_kw = dict(max_batch=args.max_batch, max_len=args.max_len,
                     prefill_buckets=(16, 32, 64),
                     collect_stats=not args.no_hdp, attn=spec,
                     prefix_cache=args.prefix_cache,
                     decode_horizon=args.decode_horizon,
                     spec_decode=args.spec_decode,
                     draft_len=args.draft_len,
                     adaptive_spec=getattr(args, "adaptive_spec", None),
                     tuner=tuner,
                     stream_sched=stream, sched=sched_cfg,
                     tp=getattr(args, "tp", None))
    fault_plan = getattr(args, "fault_plan", None)
    if dp > 1:
        eng = ReplicaSet.build(cfg, dp, faults=fault_plan, **engine_kw)
        engines = eng.engines
    else:
        eng = Engine(cfg, faults=fault_plan, **engine_kw)
        engines = [eng]
    eng0 = engines[0]
    if getattr(args, "warmup", False):
        # one throwaway request PER REPLICA compiles the prefill/decode
        # jits (same max_new as the real batch, so every fused-loop scan
        # length the drain will need is warm), then the counters restart
        # from zero. Fault injection is paused and step numbering restarts
        # afterwards, so scheduled fault steps index the measured traffic.
        paused = [e.faults for e in engines]
        for e in engines:
            e.faults = None
        for e in engines:
            e.submit(Request(-1, [1, 2, 3, 4], max_new_tokens=args.max_new))
            e.run()
            e._results.pop(-1, None)
        for e, f in zip(engines, paused):
            e.faults = f
            e._cur_step = 0
        eng.reset_metrics()
    if args.shared_prefix \
            and args.max_len - args.max_new - args.shared_prefix < 5:
        raise SystemExit(
            f"--shared-prefix {args.shared_prefix} leaves no room for "
            f"prompt tails: need max_len >= shared_prefix + max_new + 5 "
            f"(max_len {args.max_len}, max_new {args.max_new})")
    rng = np.random.default_rng(args.seed)
    shared = rng.integers(1, cfg.vocab_size,
                          size=args.shared_prefix).tolist()
    prompts = []
    for uid in range(args.requests):
        hi = min(48, args.max_len - args.max_new - args.shared_prefix)
        plen = int(rng.integers(4, max(hi, 5)))
        prompts.append(shared
                       + rng.integers(1, cfg.vocab_size, size=plen).tolist())

    arrival_rate = getattr(args, "arrival_rate", 0.0) or 0.0
    if arrival_rate > 0 and eng0.sched is None:
        raise SystemExit("--arrival-rate needs --stream-sched")
    if arrival_rate > 0:
        # Poisson arrivals in engine-step time, drawn AFTER the prompts
        # so the prompt stream (and tokens_fp) matches the static and
        # solo A/B legs token for token
        gaps = rng.exponential(1.0 / arrival_rate, size=args.requests)
        arrive = np.floor(np.cumsum(gaps)).astype(int)
        pending = list(range(args.requests))
        step = 0
        while pending or eng._n_pending():
            while pending and arrive[pending[0]] <= step:
                uid = pending.pop(0)
                eng.submit(Request(uid, prompts[uid],
                                   max_new_tokens=args.max_new))
            eng.step()
            step += 1
            if step > 100_000:
                raise SystemExit("serve: arrival loop exceeded 100k steps")
        results = eng.results()
    else:
        for uid, prompt in enumerate(prompts):
            eng.submit(Request(uid, prompt, max_new_tokens=args.max_new))
        results = eng.run()
    if dp > 1:
        # replica-0 summary carries the shape/backends; throughput and
        # counter fields are re-aggregated over the fleet
        fleet = eng.summary()
        subs = fleet["replicas"]
        s = dict(subs[0])
        for k in ("tokens_out", "decode_s", "prefill_s", "prefill_calls",
                  "prefill_tokens", "decode_steps", "cache_bytes",
                  "req_cancelled", "req_deadline", "req_errors",
                  "sched_preempted", "watchdog_shed", "faults_injected",
                  "queue_rejected"):
            s[k] = sum(sub.get(k, 0) for sub in subs)
        if s.get("decode_s"):
            s["decode_tok_s"] = s["tokens_out"] / s["decode_s"]
        for k in ("block_sparsity", "head_sparsity", "page_sparsity"):
            vals = [sub.get(k, 0.0) for sub in subs]
            s[k] = sum(vals) / len(vals)
        for k in ("health", "failovers", "requests_failed_over",
                  "replica_queue_depth", "replica_inflight",
                  "replica_last_step_s", "fault_plan", "faults_fired"):
            if k in fleet:
                s[k] = fleet[k]
        s["requests_per_replica"] = fleet["requests_per_replica"]
    else:
        s = eng.summary()
    done = sum(len(r.tokens) == args.max_new for r in results.values())
    # order-independent fingerprint of every generated token — the A/B's
    # byte-identity check (prefix-cache hit vs cold must agree exactly)
    tokens_fp = hash(tuple(sorted(
        (u, tuple(r.tokens)) for u, r in results.items()))) & 0xffffffff
    out = {
        "requests": args.requests,
        "completed": done,
        "backend": s["cache_backend"],
        # resolved (post-fallback) attention backends, one per phase — the
        # attributable ground truth for benchmark A/B rows
        "attn_prefill": s["attn_backend_prefill"],
        "attn_decode": s["attn_backend_decode"],
        "decode_horizon": eng0.horizon,
        "decode_tok_s": round(s.get("decode_tok_s", 0.0), 2),
        "prefill_s_total": round(s["prefill_s"], 3),
        "prefill_calls": s["prefill_calls"],
        # tokens run through prefill forwards (padded size) — the
        # deterministic FLOPs proxy; prefix-cache hits shrink it
        "prefill_tokens": int(s["prefill_tokens"]),
        "decode_steps": s["decode_steps"],
        "block_sparsity": round(s["block_sparsity"], 4),
        "head_sparsity": round(s["head_sparsity"], 4),
        "page_sparsity": round(s["page_sparsity"], 4),
        "kv_dtype": s["kv_dtype"],
        "kv_scale": s.get("kv_scale", "grid"),
        "cache_bytes": s["cache_bytes"],
        "tokens_fp": tokens_fp,
        "spec_decode": s["spec_decode"],
        "stream_sched": s["stream_sched"],
        "attn_policy": s["attn_policy"],
        "tp": int(s.get("tp", 1)),
        "dp": dp,
    }
    # request-lifecycle accounting: every submitted request must come back
    # as SOME typed Result even under injected faults — "lost" (no Result
    # at all) is the failure mode the fault harness exists to catch
    out["requests_ok"] = sum(r.status == "ok" for r in results.values())
    out["requests_failed"] = sum(
        r.status != "ok" for r in results.values())
    out["requests_lost"] = args.requests - len(results)
    if "fault_plan" in s:
        out["fault_plan"] = s["fault_plan"]
        out["faults_fired"] = int(s["faults_fired"])
        out["req_cancelled"] = int(s.get("req_cancelled", 0))
        out["req_deadline"] = int(s.get("req_deadline", 0))
        out["req_errors"] = int(s.get("req_errors", 0))
        out["sched_preempted"] = int(s.get("sched_preempted", 0))
        out["watchdog_shed"] = int(s.get("watchdog_shed", 0))
    if "mesh_shape" in s:
        out["mesh"] = s["mesh_shape"]
        out["cache_bytes_pool_per_shard"] = s["cache_bytes_pool_per_shard"]
        out["collective_bytes_per_layer"] = s["collective_bytes_per_layer"]
    if dp > 1:
        out["requests_per_replica"] = s["requests_per_replica"]
        out["replica_health"] = s.get("health", [])
        out["failovers"] = int(s.get("failovers", 0))
        out["requests_failed_over"] = int(s.get("requests_failed_over", 0))
        out["replica_queue_depth"] = s.get("replica_queue_depth", [])
        out["replica_inflight"] = s.get("replica_inflight", [])
        out["replica_last_step_s"] = [
            round(float(v), 5) for v in s.get("replica_last_step_s", [])]
    if "meas_decode_step_s" in s:
        out["meas_decode_step_s"] = round(s["meas_decode_step_s"], 6)
    if s["attn_policy"] == "cost":
        out.update(tuner_hits=int(s.get("tuner_hits", 0)),
                   tuner_misses=int(s.get("tuner_misses", 0)),
                   tuner_probes=int(s.get("tuner_probes", 0)),
                   tuner_cached=int(s.get("tuner_cached", 0)))
        if "pred_decode_step_s" in s:
            out["pred_decode_step_s"] = round(s["pred_decode_step_s"], 6)
        if tuner_cache and eng0.tuner is not None:
            eng0.tuner.save(tuner_cache)   # warm-start the next run
    if s["stream_sched"]:
        out.update(
            sched_admitted=int(s["sched_admitted"]),
            sched_recycled=int(s["sched_recycled"]),
            sched_deferred=int(s["sched_deferred"]),
            sched_chunk_tokens=int(s["sched_chunk_tokens"]),
            sched_interleaved_steps=int(s["sched_interleaved_steps"]),
            queue_depth_peak=int(s["queue_depth_peak"]),
            queue_depth_mean=round(s.get("queue_depth_mean", 0.0), 3),
            ttft_s_mean=round(s.get("ttft_s_mean", 0.0), 4),
            ttft_s_p95=round(s.get("ttft_s_p95", 0.0), 4),
            tpot_s_mean=round(s.get("tpot_s_mean", 0.0), 5),
            queue_wait_s_mean=round(s.get("queue_wait_s_mean", 0.0), 4))
    if s["spec_decode"]:
        out.update(draft_len=s["draft_len"],
                   spec_rounds=int(s["spec_rounds"]),
                   draft_tokens=int(s["draft_tokens"]),
                   accepted_tokens=int(s["accepted_tokens"]),
                   acceptance_rate=round(s["acceptance_rate"], 4),
                   attn_draft=s["attn_backend_draft"],
                   attn_verify=s["attn_backend_verify"],
                   adaptive_spec=s["adaptive_spec"])
        if s["adaptive_spec"]:
            out.update(
                acceptance_ema=round(s["acceptance_ema"], 4),
                draft_len_mean=round(s["draft_len_mean"] or 0.0, 3))
    if s["cache_backend"] == "paged":
        out["pages_peak"] = s["pages_peak"]
        out["pages_in_use"] = s["pages_in_use"]
        # resident-footprint accounting by storage dtype: pool bytes over
        # every leaf (codes + per-page scales) and the per-token rate
        out["cache_bytes_pool"] = s["cache_bytes_pool"]
        out["cache_bytes_per_token"] = round(s["cache_bytes_per_token"], 2)
        out["prefix_cache"] = s["prefix_cache"]
        if s["prefix_cache"]:
            out.update(prefix_hits=s["prefix_hits"],
                       prefix_hit_tokens=s["prefix_hit_tokens"],
                       prefix_evictions=s["prefix_evictions"],
                       pages_cached=s["pages_cached"],
                       cow_copies=int(s["cow_copies"]))
    log.info("serve summary: %s", out)
    return out


def main(argv=None) -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    args = build_parser().parse_args(argv)
    out = run(args)
    if out.get("fault_plan"):
        # under injected faults some requests fail BY DESIGN — success is
        # "no request lost": every submission came back as a typed Result
        return 0 if out["requests_lost"] == 0 else 1
    return 0 if out["completed"] == out["requests"] else 1


if __name__ == "__main__":
    sys.exit(main())
