"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 50 --checkpoint-dir /tmp/ckpt

On the real cluster this binary runs once per host under the TPU runtime
(mesh from --mesh single|multi); in this container it runs the same code
path on CPU with --reduced (tiny same-family config) or --mesh cpu.
Features exercised end-to-end: sharded step (steps.build_train_step),
deterministic host-sharded data, grad accumulation, ZeRO-1 optimizer
sharding, bf16 gradient compression, atomic checkpoints + resume,
watchdog + straggler log, retry-with-restore.
"""
from __future__ import annotations

import argparse
import logging
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig, reduced
from repro.data.pipeline import DataConfig, Prefetcher, host_slice, make_source
from repro.distribution.sharding import logical_axis_rules
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.training import checkpoint as ckpt
from repro.training import fault
from repro.training import optimizer as opt

log = logging.getLogger("repro.train")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["cpu", "single", "multi"],
                    default="cpu")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--grad-compression", choices=["none", "bf16"],
                    default="none")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-interval", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--watchdog-s", type=float, default=600.0)
    ap.add_argument("--data", choices=["synthetic", "memorize"],
                    default="synthetic")
    return ap


def _mesh_for(args):
    if args.mesh == "cpu":
        dev = np.asarray(jax.devices())
        return jax.sharding.Mesh(dev.reshape(len(dev), 1), ("data", "model"))
    return make_production_mesh(multi_pod=args.mesh == "multi")


def run(args) -> dict:
    cfg: ModelConfig = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape: ShapeConfig = SHAPES[args.shape]
    if args.seq_len or args.global_batch:
        shape = ShapeConfig(shape.name, args.seq_len or shape.seq_len,
                            args.global_batch or shape.global_batch, "train")
    if args.reduced and not (args.seq_len or args.global_batch):
        shape = ShapeConfig("train_smoke", 64, 8, "train")

    mesh = _mesh_for(args)
    log.info("mesh %s  arch %s  params %.2fM", dict(mesh.shape), cfg.name,
             registry.param_count(cfg) / 1e6)

    with mesh, logical_axis_rules(mesh, {}):
        built = steps_lib.build_train_step(
            cfg, shape, mesh, num_microbatches=args.microbatches,
            grad_compression=args.grad_compression)

        with logical_axis_rules(mesh, built.rules):
            p_sh, o_sh = built.jitted.in_shardings[:2] \
                if hasattr(built.jitted, "in_shardings") else (None, None)

            def init_state():
                params, _ = registry.init_params(
                    cfg, jax.random.PRNGKey(args.seed))
                return {"params": params,
                        "opt": opt.init_opt_state(params)}

            step0 = 0
            if args.checkpoint_dir:
                mgr = ckpt.CheckpointManager(
                    args.checkpoint_dir, interval=args.checkpoint_interval)
                like = {"params": built.args[0], "opt": built.args[1]}
                state, step0, _ = mgr.restore_or(like, init_state)
                if step0:
                    log.info("resumed from step %d", step0)
            else:
                mgr = None
                state = init_state()
            params, opt_state = state["params"], state["opt"]

            dcfg = DataConfig(cfg.vocab_size, shape.seq_len,
                              shape.global_batch, seed=args.seed,
                              kind=args.data)
            source = make_source(dcfg)
            timer = fault.StepTimer()
            hung = {"flag": False}
            losses = []

            def on_timeout():
                hung["flag"] = True
                log.error("watchdog fired — requesting stop+checkpoint")

            t_start = time.time()
            with fault.Watchdog(args.watchdog_s, on_timeout) as wd, \
                    Prefetcher(source, start_step=step0,
                               sl=host_slice(shape.global_batch)) as stream:
                for step in range(step0, step0 + args.steps):
                    if hung["flag"]:
                        break
                    batch_np = next(stream)
                    timer.start()

                    def one_step(p, o, b):
                        return built.jitted(p, o, {"tokens": b})

                    def on_retry(attempt, exc):
                        nonlocal params, opt_state
                        if mgr is not None:
                            like = {"params": built.args[0],
                                    "opt": built.args[1]}
                            st, _, _ = mgr.restore_or(like, init_state)
                            params, opt_state = st["params"], st["opt"]

                    params, opt_state, metrics = fault.retry(
                        one_step, params, opt_state, batch_np["tokens"],
                        on_retry=on_retry)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    dt = timer.stop(step)
                    wd.beat()
                    if step % args.log_every == 0:
                        log.info("step %5d  loss %.4f  %.3fs", step, loss, dt)
                    if mgr is not None:
                        mgr.maybe_save(step + 1,
                                       {"params": params, "opt": opt_state},
                                       meta={"loss": loss})
                if mgr is not None:
                    mgr.save(step0 + len(losses),
                             {"params": params, "opt": opt_state},
                             meta={"loss": losses[-1] if losses else None})

    out = {
        "steps": len(losses),
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": time.time() - t_start,
        **{f"timer_{k}": v for k, v in timer.summary().items()},
    }
    log.info("done: %s", out)
    return out


def main(argv=None) -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    args = build_parser().parse_args(argv)
    out = run(args)
    ok = out["steps"] > 0 and np.isfinite(out["last_loss"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
