import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, print memory/cost analysis, dump roofline JSON.

MUST be run as its own process (the XLA flag above is read at first jax
init):  PYTHONPATH=src python -m repro.launch.dryrun [--arch a] [--shape s]
        [--multi-pod/--single-pod/--both] [--out results.json]
"""
import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import SHAPES, cell_applicable, get_config, list_configs  # noqa: E402
from repro.distribution.sharding import logical_axis_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402
from repro.roofline import analysis  # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             step_kwargs=None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        with mesh, logical_axis_rules(mesh, {}):
            built = build_step(cfg, shape, mesh, **(step_kwargs or {}))
            with logical_axis_rules(mesh, built.rules):
                lowered = built.jitted.lower(*built.args)
                compiled = lowered.compile()
                mem = compiled.memory_analysis()
                roof = analysis.analyze(
                    compiled,
                    model_flops_per_device=analysis.model_flops(
                        cfg, shape, n_dev))
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            n_devices=n_dev,
            num_microbatches=built.meta.get("num_microbatches"),
            memory={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "peak_bytes": int(mem.temp_size_in_bytes
                                  + mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  - mem.alias_size_in_bytes),
            },
            roofline=roof.as_dict(),
            fits_hbm=bool(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                          + mem.output_size_in_bytes - mem.alias_size_in_bytes
                          < analysis.HBM_BYTES),
        )
        if verbose:
            m = rec["memory"]
            r = rec["roofline"]
            print(f"[{rec['mesh']}] {arch} x {shape_name}: OK "
                  f"({rec['compile_s']}s) peak={m['peak_bytes']/1e9:.2f}GB "
                  f"fits={rec['fits_hbm']} flops={r['flops']:.3e} "
                  f"bottleneck={r['bottleneck']} "
                  f"(c={r['compute_t']*1e3:.2f}ms m={r['memory_t']*1e3:.2f}ms "
                  f"l={r['collective_t']*1e3:.2f}ms)", flush=True)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug, record it
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAIL {e}",
                  flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(list_configs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for multi in meshes:
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_name) in done:
                    continue
                rec = run_cell(arch, shape, multi_pod=multi)
                results.append(rec)
                json.dump(results, open(args.out, "w"), indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip (documented), {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
