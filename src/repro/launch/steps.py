"""Step builders: abstract state + shardings + jit'd step per (arch, shape).

Shared by the dry-run (lower/compile on placeholder devices), the real
launchers (train.py / serve.py) and the benchmarks — one code path, so the
dry-run proves exactly what production would run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distribution import sharding as shd
from repro.models import registry
from repro.training import optimizer as opt
from repro.training.train_loop import (
    make_decode_step, make_prefill_step, make_train_step)

FSDP_THRESHOLD = 8e9


@dataclasses.dataclass
class BuiltStep:
    fn: Callable
    jitted: Any
    args: Tuple           # abstract args (ShapeDtypeStructs)
    rules: Dict
    meta: Dict


def choose_rules(cfg: ModelConfig, kind: str, mesh: Mesh,
                 *, fsdp: Optional[bool] = None,
                 seq_shard_prefill: bool = True) -> Dict:
    """Pick logical->physical rules for this (arch, shape kind, mesh)."""
    big = registry.param_count(cfg) >= FSDP_THRESHOLD if fsdp is None else fsdp
    rules = dict(shd.RULES_FSDP_TP if big else shd.RULES_TP)
    msz = mesh.shape.get("model", 1)
    if kind == "prefill" and seq_shard_prefill:
        # context parallelism: activations + cache sharded over sequence
        rules["seq_act"] = "model"
    if cfg.n_heads and cfg.n_heads % msz:
        # heads can't shard over `model` (e.g. 40 heads on 16-way TP):
        # the heads_act rule resolves to None and attention activations
        # ([B,H,S,chunk] f32 score slabs) replicate. Fall back to
        # sequence sharding so those slabs still split `model`-ways.
        rules["seq_act"] = "model"
    if big and cfg.n_experts:
        # large MoE (llama4-scout, 109B): FSDP-style weight gathers get
        # hoisted out of the layer scan by XLA (whole gathered stack
        # live at once -> OOM). Instead shard experts over `data` (EP:
        # tokens all-to-all to their expert's devices — they are already
        # batch-sharded over data) and the expert mlp dim over `model`
        # (TP): 2D weight sharding with NO gather at use. Attention/
        # embed weights stay model-sharded (small) instead of FSDP.
        # expert weights resolve to (experts=data, embed=dropped-by-dedup,
        # mlp=model); dense weights keep the FSDP embed->data sharding and
        # ZeRO-1 optimizer sharding.
        dsz = mesh.shape.get("data", 1)
        if cfg.n_experts % dsz == 0:
            rules["experts"] = "data"
            rules["experts_act"] = "data"
    if kind in ("prefill", "decode"):
        if cfg.n_kv_heads and cfg.n_kv_heads % msz == 0:
            rules["kv_heads"], rules["kv_seq"] = "model", None
        else:
            rules["kv_heads"], rules["kv_seq"] = None, "model"
    return rules


def _shardings_for(tree, logical, mesh, rules, zero1=False):
    def one(x, ax):
        ax = tuple(ax)
        spec = (shd.zero1_spec(ax, x.shape, mesh, rules) if zero1
                else shd.spec_for(ax, x.shape, mesh, rules))
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, tree, logical)


def _batch_shardings(batch_abs, mesh, rules):
    def one(x):
        ax = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, shd.spec_for(ax, x.shape, mesh, rules))
    return jax.tree.map(one, batch_abs)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def micro_batches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  micro_tokens: int = 4096) -> int:
    """Grad-accumulation factor: per-device microbatch ~micro_tokens."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if shape.global_batch % dp:
        dp = 1  # batch replicated (e.g. long_500k B=1)
    b_local = shape.global_batch // dp
    want = max(1, (b_local * shape.seq_len)
               // max(micro_tokens, shape.seq_len))
    m = min(want, b_local)
    while m > 1 and (shape.global_batch % m
                     or (shape.global_batch // m) % dp):
        m -= 1
    return m


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, rules: Optional[Dict] = None,
                     num_microbatches: Optional[int] = None,
                     grad_compression: str = "none",
                     opt_cfg: Optional[opt.OptConfig] = None) -> BuiltStep:
    rules = rules or choose_rules(cfg, "train", mesh)
    nm = num_microbatches or micro_batches(cfg, shape, mesh)
    # NOTE: scanning the Adam update over layer stacks (lax.map) was
    # measured and REFUTED: the map's stacked outputs cannot alias the
    # donated optimizer buffers, so peak grew 17.7 -> 26.6 GB (perf log
    # A5). Keep the flat per-leaf update.
    big = registry.param_count(cfg) >= FSDP_THRESHOLD
    params_abs, specs = registry.abstract_params(cfg)
    opt_abs = jax.eval_shape(opt.init_opt_state, params_abs)
    batch_abs = registry.input_specs(cfg, shape)["batch"]

    p_sh = _shardings_for(params_abs, specs, mesh, rules)
    o_sh = {
        "step": _replicated(mesh),
        "m": _shardings_for(opt_abs["m"], specs, mesh, rules, zero1=True),
        "v": _shardings_for(opt_abs["v"], specs, mesh, rules, zero1=True),
        "master": _shardings_for(opt_abs["master"], specs, mesh, rules,
                                 zero1=True),
    }
    b_sh = _batch_shardings(batch_abs, mesh, rules)

    fn = make_train_step(cfg, opt_cfg or opt.OptConfig(),
                         num_microbatches=nm,
                         grad_compression=grad_compression,
                         param_shardings=p_sh,
                         accum_dtype=jnp.bfloat16 if big else jnp.float32)
    jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    return BuiltStep(fn, jitted, (params_abs, opt_abs, batch_abs), rules,
                     {"num_microbatches": nm, "kind": "train"})


def _cache_abs(cfg, shape: ShapeConfig, kind: str):
    B = shape.global_batch
    max_len = registry.decode_cache_len(cfg, shape)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_len"] = (shape.seq_len if kind == "prefill"
                         else (cfg.max_source_positions or 1500))
    if kind == "prefill":
        max_len = shape.seq_len
    return jax.eval_shape(
        lambda: registry.init_cache(cfg, B, max_len=max_len, **kw))


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       *, rules: Optional[Dict] = None) -> BuiltStep:
    rules = rules or choose_rules(cfg, "prefill", mesh)
    params_abs, specs = registry.abstract_params(cfg)
    batch_abs = registry.input_specs(cfg, shape)["batch"]
    cache_abs = _cache_abs(cfg, shape, "prefill")
    c_specs = registry.cache_specs(cfg)

    p_sh = _shardings_for(params_abs, specs, mesh, rules)
    b_sh = _batch_shardings(batch_abs, mesh, rules)
    c_sh = _shardings_for(cache_abs, c_specs, mesh, rules)

    fn = make_prefill_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
    return BuiltStep(fn, jitted, (params_abs, batch_abs, cache_abs), rules,
                     {"kind": "prefill"})


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, rules: Optional[Dict] = None) -> BuiltStep:
    rules = rules or choose_rules(cfg, "decode", mesh)
    params_abs, specs = registry.abstract_params(cfg)
    ins = registry.input_specs(cfg, shape)
    cache_abs = _cache_abs(cfg, shape, "decode")
    c_specs = registry.cache_specs(cfg)

    p_sh = _shardings_for(params_abs, specs, mesh, rules)
    t_sh = _batch_shardings(ins["token"], mesh, rules)
    c_sh = _shardings_for(cache_abs, c_specs, mesh, rules)

    fn = make_decode_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh, None),
                     out_shardings=(t_sh, None, c_sh), donate_argnums=(2,))
    return BuiltStep(fn, jitted,
                     (params_abs, ins["token"], cache_abs, ins["pos"]),
                     rules, {"kind": "decode"})


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **kw
               ) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, **kw)
    return build_decode_step(cfg, shape, mesh, **kw)
