"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only data-parallel gradient reduction (hierarchical: reduce-scatter inside
a pod over `data`, then cross-pod all-reduce over `pod` — DCN-friendly).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={need} before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_serving_mesh(tp: int = 1, dp: int = 1,
                      devices=None) -> jax.sharding.Mesh:
    """Small serving mesh: (data=dp, model=tp) over whatever devices exist.

    Unlike `make_production_mesh` this builds from the devices actually
    present (host-CPU friendly: set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before first jax
    init to fake N devices). `tp` shards KV-head/pool state, `dp` is the
    engine-replica axis.
    """
    tp, dp = int(tp), int(dp)
    if tp < 1 or dp < 1:
        raise ValueError(f"make_serving_mesh: tp={tp} dp={dp} must be >= 1")
    devs = list(jax.devices()) if devices is None else list(devices)
    need = tp * dp
    if len(devs) < need:
        raise RuntimeError(
            f"serving mesh (dp={dp}, tp={tp}) needs {need} devices, have "
            f"{len(devs)} — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "before any jax import")
    return jax.make_mesh((dp, tp), ("data", "model"), devices=devs[:need])


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
