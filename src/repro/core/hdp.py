"""Hybrid Dynamic Pruning attention — faithful Algorithm 2 + batched fast path.

Two implementations with identical semantics:

* :func:`hdp_attention_reference` — term-by-term transliteration of the
  paper's Algorithm 2 (Integer_atten + Frac1 + Frac2, explicit mask loop
  expressed as array ops). Used as the oracle in tests/benchmarks.
* :func:`hdp_attention` — production path. Uses the algebraic identity
  ``IQ·IKᵀ + IQ·FKᵀ + FQ·IKᵀ == QKᵀ − FQ·FKᵀ`` so the approximation costs
  two MXU matmuls (one shared with the scout), and is fully batched over
  [..., L, D] leading dims. Every leading index is treated as one "head"
  for the head-pruning gate (i.e. per-(batch, head) gating).

Both operate on a single attention head of shape [..., L, d_h]; models vmap
or batch over (batch, heads).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import blocking
from repro.core.config import HDPConfig
from repro.core.quant import calib_scale, quantize_and_split


def calibrated_split(x: jnp.ndarray, cfg: HDPConfig):
    """(scale, xq, I, F) with x*scale snapped to the fixed-point grid."""
    s = calib_scale(x, cfg.int_bits, cfg.calib)
    xq, i, f = quantize_and_split(x * s.astype(x.dtype),
                                  cfg.int_bits, cfg.frac_bits)
    return s, xq, i, f


def decode_scout(int_scores: jnp.ndarray, valid: jnp.ndarray, cfg: HDPConfig,
                 per_query: bool = False):
    """Decode-shaped integer scout: one block row per head over KV pages.

    ``int_scores`` [..., Sq, Sk] are integer-part attention scores for a
    (small) decode query group; Sk must be a multiple of ``cfg.block_k``.
    The whole query extent pools into a single row of Sk/block_k blocks —
    with a block-paged KV cache these blocks ARE the cache pages, so the
    keep mask doubles as the page fetch list (Fetch-Upon-Mask). ``valid``
    is a positionally-broadcastable bool mask [..., Sq, Sk].

    ``per_query`` keeps the Sq axis instead of pooling it: each query row
    gets its own block row, importances and head gate, exactly as if it
    had run through ``Sq`` independent single-row scouts. This is the
    speculative-verify shape — row ``j`` of a multi-query verify call
    must reproduce the keep mask its own sequential decode step would
    have computed, or exact-match acceptance loses token identity.

    Returns (keep, bvalid, theta, theta_head, head_kept), where ``[...]``
    below gains a trailing Sq axis when ``per_query``:
      keep [..., nk] bool      — pages that survive block pruning
      bvalid [..., nk] bool    — pages with any valid position
      theta [..., nk] f32      — block importances
      theta_head [...]         — head importances (normalized per cfg)
      head_kept [...] bool     — early head gate
    """
    if per_query:
        # insert a singleton pooled-q axis per row: the pooled math below
        # then reduces over one query at a time, yielding [..., Sq, nk]
        # (valid carries the Sq axis — _mask_bias always composes q
        # validity in — so the same insertion keeps them aligned)
        int_scores = int_scores[..., :, None, :]
        valid = valid[..., :, None, :]
    theta, bvalid = blocking.pooled_block_theta(int_scores, valid, cfg.block_k)
    if cfg.block_pruning:
        thr = blocking.row_threshold(theta, cfg.rho_b, bvalid)
        keep = blocking.block_keep_mask(theta, thr, bvalid)
    else:
        keep = jnp.broadcast_to(bvalid, theta.shape)
    theta_head = jnp.where(bvalid, theta, 0.0).sum(-1)
    if cfg.normalize_head_score:
        theta_head = theta_head / jnp.maximum(
            valid.sum(axis=(-2, -1)).astype(jnp.float32), 1.0)
    head_kept = (theta_head > cfg.tau_h) if cfg.head_pruning \
        else jnp.ones_like(theta_head, bool)
    return keep, bvalid, theta, theta_head, head_kept


@dataclasses.dataclass
class HDPStats:
    """Diagnostics emitted by an HDP attention call (all jnp arrays)."""

    keep_blocks: jnp.ndarray      # bool [..., R, C]
    head_kept: jnp.ndarray        # bool [...]
    theta: jnp.ndarray            # [..., R, C] block importances
    theta_head: jnp.ndarray       # [...] head importances (possibly normalized)
    threshold: jnp.ndarray        # [..., R, 1] row thresholds
    block_sparsity: jnp.ndarray   # scalar: pruned-block fraction in kept heads
    head_sparsity: jnp.ndarray    # scalar: pruned-head fraction
    net_sparsity: jnp.ndarray     # scalar: Fig. 10 accounting


def _pad_to_blocks(x: jnp.ndarray, bq: int, axis: int) -> jnp.ndarray:
    l = x.shape[axis]
    pad = (-l) % bq
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _scout_and_mask(iq, ik, cfg: HDPConfig, lq, lk, q_offset, kv_len=None):
    """Integer scout matmul -> block stats -> (keep_blocks, head_kept, aux).

    Returns everything on padded block geometry; caller crops.
    """
    bq, bk = cfg.block_q, cfg.block_k
    integer_atten = jnp.einsum("...qd,...kd->...qk", iq, ik)

    # Valid-entry mask (causal and/or KV length bounded).
    elem_valid = None
    if cfg.causal:
        elem_valid = blocking.causal_element_mask(iq.shape[-2], ik.shape[-2], q_offset)
    if kv_len is not None:
        kmask = (jnp.arange(ik.shape[-2]) < kv_len)[None, :]
        elem_valid = kmask if elem_valid is None else jnp.logical_and(elem_valid, kmask)
    pad_q = iq.shape[-2] - lq
    pad_k = ik.shape[-2] - lk
    if pad_q or pad_k:
        pv = jnp.zeros((iq.shape[-2], ik.shape[-2]), bool)
        pv = pv.at[: lq, : lk].set(True)
        elem_valid = pv if elem_valid is None else jnp.logical_and(elem_valid, pv)

    if elem_valid is not None:
        theta_src = jnp.where(elem_valid, integer_atten, 0.0)
        block_valid = blocking.block_abs_sum(
            elem_valid.astype(integer_atten.dtype), bq, bk) > 0
    else:
        theta_src = integer_atten
        block_valid = None

    theta = blocking.block_abs_sum(theta_src, bq, bk)
    if cfg.block_pruning:
        thresh = blocking.row_threshold(theta, cfg.rho_b, block_valid)
        keep = blocking.block_keep_mask(theta, thresh, block_valid)
    else:
        thresh = jnp.zeros_like(theta[..., :1])
        keep = jnp.ones_like(theta, bool) if block_valid is None else block_valid

    # Head importance: absolute sum over the whole integer map (line 10).
    if block_valid is not None:
        theta_head = jnp.where(block_valid, theta, 0.0).sum(axis=(-2, -1))
        n_valid = (
            elem_valid.astype(jnp.float32).sum()
            if elem_valid is not None
            else jnp.asarray(float(lq * lk))
        )
    else:
        theta_head = theta.sum(axis=(-2, -1))
        n_valid = jnp.asarray(float(lq * lk))
    if cfg.normalize_head_score:
        theta_head = theta_head / jnp.maximum(n_valid, 1.0)
    if cfg.head_pruning:
        head_kept = theta_head > cfg.tau_h  # line 19: proceed iff theta > tau
    else:
        head_kept = jnp.ones_like(theta_head, bool)
    return integer_atten, elem_valid, block_valid, theta, thresh, keep, theta_head, head_kept


def _finish(scores, keep_elem, head_kept, v, cfg: HDPConfig):
    softmax = blocking.approx_softmax if cfg.approx_softmax else blocking.masked_softmax
    prob = softmax(scores, keep_elem)
    out = jnp.einsum("...qk,...kd->...qd", prob, v)
    gate = head_kept[..., None, None].astype(out.dtype)
    return out * gate  # line 33: pruned head -> result = 0


def hdp_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: HDPConfig,
    *,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    return_stats: bool = True,
):
    """Batched HDP attention (fast path) on [..., L, d_h] tensors.

    q_offset: absolute position of q[..., 0, :] (decode); kv_len: optional
    dynamic KV validity bound. Returns (out, HDPStats|None).
    """
    if not cfg.enabled:
        scores = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(
            jnp.asarray(q.shape[-1], q.dtype))
        keep = None
        if cfg.causal:
            keep = blocking.causal_element_mask(q.shape[-2], k.shape[-2], q_offset)
        out = jnp.einsum("...qk,...kd->...qd", blocking.masked_softmax(scores, keep), v)
        return out, None

    lq, lk = q.shape[-2], k.shape[-2]
    qp = _pad_to_blocks(q, cfg.block_q, -2)
    kp = _pad_to_blocks(k, cfg.block_k, -2)
    vp = _pad_to_blocks(v, cfg.block_k, -2)

    sq, qq, iq, fq = calibrated_split(qp, cfg)
    sk, kq, ik, fk = calibrated_split(kp, cfg)

    (_, elem_valid, _, theta, thresh, keep, theta_head, head_kept) = _scout_and_mask(
        iq, ik, cfg, lq, lk, q_offset, kv_len)

    # approx = QK^T - FQ.FK^T  (== Integer + Frac1 + Frac2 exactly);
    # 1/(s_q*s_k) maps scores back from the calibrated domain.
    scores = jnp.einsum("...qd,...kd->...qk", qq, kq)
    if cfg.approx:
        scores = scores - jnp.einsum("...qd,...kd->...qk", fq, fk)
    scores = scores / (sq * sk).astype(scores.dtype)
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], scores.dtype))

    keep_elem = blocking.expand_block_mask(keep, cfg.block_q, cfg.block_k)
    if elem_valid is not None:
        keep_elem = jnp.logical_and(keep_elem, elem_valid)

    out = _finish(scores, keep_elem, head_kept, vp, cfg)[..., :lq, :]

    stats = None
    if return_stats:
        block_valid = None
        if elem_valid is not None:
            block_valid = blocking.block_abs_sum(
                elem_valid.astype(jnp.float32), cfg.block_q, cfg.block_k) > 0
        bsp, hsp, net = blocking.net_sparsity(
            keep, head_kept[..., None, None], block_valid)
        stats = HDPStats(keep, head_kept, theta, theta_head, thresh, bsp, hsp, net)
    return out, stats


def hdp_attention_reference(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, cfg: HDPConfig,
    *, q_offset: int = 0,
):
    """Literal Algorithm 2: three-term approximation, explicit mask algebra.

    Slow/materializing; the oracle for tests and paper-fidelity benchmarks.
    """
    lq, lk = q.shape[-2], k.shape[-2]
    qp = _pad_to_blocks(q, cfg.block_q, -2)
    kp = _pad_to_blocks(k, cfg.block_k, -2)
    vp = _pad_to_blocks(v, cfg.block_k, -2)
    sq, _, iq, fq = calibrated_split(qp, cfg)
    sk, _, ik, fk = calibrated_split(kp, cfg)

    (integer_atten, elem_valid, _, theta, thresh, keep, theta_head, head_kept
     ) = _scout_and_mask(iq, ik, cfg, lq, lk, q_offset)

    # Lines 19-28: fractional terms only where Mask == 1 (we compute them
    # densely and mask — numerically identical, since masked entries are
    # excluded from the softmax anyway).
    frac1 = jnp.einsum("...qd,...kd->...qk", iq, fk)
    frac2 = jnp.einsum("...qd,...kd->...qk", fq, ik)
    approximation = integer_atten + frac1 + frac2
    if not cfg.approx:
        approximation = approximation + jnp.einsum("...qd,...kd->...qk", fq, fk)
    approximation = approximation / (sq * sk).astype(approximation.dtype)
    scores = approximation / jnp.sqrt(jnp.asarray(q.shape[-1], approximation.dtype))

    keep_elem = blocking.expand_block_mask(keep, cfg.block_q, cfg.block_k)
    if elem_valid is not None:
        keep_elem = jnp.logical_and(keep_elem, elem_valid)
    out = _finish(scores, keep_elem, head_kept, vp, cfg)[..., :lq, :]
    stats = HDPStats(
        keep, head_kept, theta, theta_head, thresh,
        *blocking.net_sparsity(keep, head_kept[..., None, None], None))
    return out, stats


def dense_attention_reference(q, k, v, *, causal=False, q_offset=0):
    """Exact (unquantized, unpruned) attention — the fidelity yardstick."""
    scores = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(
        jnp.asarray(q.shape[-1], q.dtype))
    keep = None
    if causal:
        keep = blocking.causal_element_mask(q.shape[-2], k.shape[-2], q_offset)
    prob = blocking.masked_softmax(scores, keep)
    return jnp.einsum("...qk,...kd->...qd", prob, v)
