"""Fixed-point quantization and integer/fraction split (paper Sec. III).

The paper assumes Q/K/V arrive quantized in 16-bit fixed point and bases
every pruning decision on the *integer parts* only. We keep values in float
containers but snap them to the fixed-point grid, so the integer/fractional
decomposition and the scout matmul are exact (int32-representable).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_fixed(x: jnp.ndarray, int_bits: int = 4, frac_bits: int = 12) -> jnp.ndarray:
    """Quantize to signed fixed point Q(int_bits).(frac_bits).

    Range is [-2^int_bits, 2^int_bits - 2^-frac_bits]; resolution 2^-frac_bits.
    Returned values live on the grid but keep x.dtype (float) so downstream
    matmuls stay on the MXU.
    """
    scale = jnp.asarray(2.0**frac_bits, x.dtype)
    lo = -(2.0**int_bits)
    hi = 2.0**int_bits - 2.0 ** (-frac_bits)
    q = jnp.round(x * scale) / scale
    return jnp.clip(q, lo, hi)


def int_frac_split(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split into integer part (trunc toward zero) and fractional remainder.

    x == I + F with I integer-valued and F in (-1, 1). Near-zero values
    (|x| < 1) have I == 0 — this is what gives the paper's free near-zero
    pruning when the F*F term is dropped.
    """
    i = jnp.trunc(x)
    return i, x - i


def quantize_and_split(
    x: jnp.ndarray, int_bits: int = 4, frac_bits: int = 12
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """quantize_fixed followed by int_frac_split; returns (xq, I, F)."""
    xq = quantize_fixed(x, int_bits, frac_bits)
    i, f = int_frac_split(xq)
    return xq, i, f


# --------------------------------------------------------------------------
# Shared per-page pool quantization (int8-first serving KV store)
# --------------------------------------------------------------------------
# THE one quantization grid of the serving pool, its scout views, and the
# kernels' in-register dequant. The pool stores int8 *codes* plus a
# per-page scale; the grid step is the static power of two
# ``pool_scale(int_bits)`` so that
#
#   * dequantized values land exactly on the fixed-point grid the
#     attention maths already snaps K to (coarse 2^(int_bits-7) grid is a
#     subset of the 2^-frac_bits grid) — ``quantize_fixed`` is the
#     identity on decoded values, so every consumer downstream of a
#     dequant is untouched;
#   * multiplying by the scale is exact in fp32, so the Pallas kernel
#     (scale factored around its dots) and the XLA paths (scale applied
#     at gather) produce bit-identical scores.
#
# Code -128 never arises from encoding (codes clamp to +/-127); it is
# reserved as the *position-granular poison sentinel* — the quantized
# analogue of the NaN the debug hooks write into rejected speculative
# positions. ``decode_pool`` maps it to NaN (the stage-3 tripwire);
# ``pool_view_finite`` maps it to 0 (the stage-1 scout, which under fp32
# pools reads a separate finite copy and must stay finite here too).
# Freed-*page* poison is page-granular and travels through the per-page
# scale instead: a NaN scale poisons every dequant of the page while the
# static-grid scout views stay finite (same split as fp32 pools, where
# only ``k_pages`` was poisoned and the scout copies stayed readable).

#: reserved int8 code marking a poisoned position (never produced by
#: ``encode_pool``; decodes to NaN, scout-views to 0).
POISON_CODE = -128

#: grid of the int8 quantized-fraction scout copy / view (2^6: fractions
#: in (-1, 1) scale to +/-64, inside int8 range). Coarser than the
#: cache's ``frac_bits`` on purpose — the draft only needs argmax-grade
#: scores.
FRAC_SCOUT_SCALE = 64.0


def pool_int_bits(hdp) -> int:
    """Integer bits of the pool grid: the HDP grid when the scout runs,
    a Q4 default for HDP-off paged serving (same dynamic range)."""
    return hdp.int_bits if hdp is not None and hdp.enabled else 4


def pool_scale(int_bits: int = 4) -> float:
    """Static power-of-two step of the int8 pool grid: +/-127 codes span
    (just under) the fixed-point range +/-2^int_bits."""
    return 2.0 ** (int_bits - 7)


def encode_pool(x: jnp.ndarray, int_bits: int = 4) -> jnp.ndarray:
    """Float values -> int8 pool codes on the static grid.

    Codes clamp to [-127, 127]; -128 is reserved for poison. Inputs are
    assumed finite (the pool only ever encodes freshly-projected K/V)."""
    s = pool_scale(int_bits)
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)


def decode_pool(codes: jnp.ndarray, scale) -> jnp.ndarray:
    """int8 codes (+ broadcastable per-page scale) -> fp32 values.

    The POISON_CODE sentinel decodes to NaN so position-granular poison
    survives quantization; a NaN scale poisons the whole page."""
    c = codes.astype(jnp.float32)
    c = jnp.where(codes == POISON_CODE, jnp.nan, c)
    return c * jnp.asarray(scale, jnp.float32)


def pool_view_finite(codes: jnp.ndarray, int_bits: int = 4) -> jnp.ndarray:
    """Finite static-grid view of pool codes (poison -> 0, scale = grid).

    What the stage-1 scout and the draft derive their copies from: under
    fp32 pools these were separate finite int8 copies, so the views must
    ignore both poison channels — a freed/rejected page's *scores* stay
    finite (and masked); only a stage-3 read of its full-precision
    values trips NaN."""
    c = jnp.where(codes == POISON_CODE, 0, codes).astype(jnp.float32)
    return c * pool_scale(int_bits)


def roundtrip_pool(x: jnp.ndarray, int_bits: int = 4) -> jnp.ndarray:
    """Snap x to exactly what an encode/decode round trip preserves.

    Applied to K/V at *prefill* write time by quantized-pool engines, so
    the dense request cache, the page pool, prefix-cache hits and COW
    tails all hold the same values — paged-vs-paged token identity is
    exact, and only the fp32-vs-int8 A/B sees quantization drift."""
    s = pool_scale(int_bits)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127) * s


def absmax_page_scale(x: jnp.ndarray, int_bits: int = 4) -> jnp.ndarray:
    """Per-page per-kv-head calibrated absmax scale.

    ``x`` is a page-shaped slab [..., ps, N, hd]; the scale spans the
    page's positions and head dim per KV head: s = max|x| / 127, so the
    largest value in the page maps to code +/-127 (full int8 range
    instead of the static grid's fixed step). All-zero pages fall back
    to the static grid step ``pool_scale(int_bits)`` so a fresh page
    keeps a finite, nonzero scale (NaN scales are the freed-page poison
    channel and must never arise from encoding). Returns [..., N]."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    s0 = jnp.asarray(pool_scale(int_bits), jnp.float32)
    return jnp.where(m > 0, m / 127.0, s0)


def encode_pool_scaled(x: jnp.ndarray, scale) -> jnp.ndarray:
    """Float values -> int8 pool codes under an explicit (per-page)
    scale, broadcastable against ``x``. Codes clamp to [-127, 127];
    -128 stays reserved for poison, exactly as on the static grid."""
    s = jnp.asarray(scale, jnp.float32)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s),
                    -127, 127).astype(jnp.int8)


def scout_int_codes(x: jnp.ndarray, int_bits: int = 4,
                    frac_bits: int = 12) -> jnp.ndarray:
    """int8 integer-scout codes of K (trunc of the fixed-point grid) —
    the write-time copy fp32 pools store and quantized pools derive."""
    xq = quantize_fixed(x.astype(jnp.float32), int_bits, frac_bits)
    return jnp.trunc(xq).astype(jnp.int8)


def scout_frac_codes(x: jnp.ndarray, int_bits: int = 4,
                     frac_bits: int = 12) -> jnp.ndarray:
    """int8 quantized-fraction scout codes of K (FRAC_SCOUT_SCALE grid)."""
    xq = quantize_fixed(x.astype(jnp.float32), int_bits, frac_bits)
    f = xq - jnp.trunc(xq)
    return jnp.round(f * FRAC_SCOUT_SCALE).astype(jnp.int8)


def calib_scale(x: jnp.ndarray, int_bits: int, mode: str) -> jnp.ndarray:
    """Per-tensor scale mapping x onto the fixed-point grid.

    The paper's co-processor receives Q/K/V already quantized by the host
    accelerator — i.e. with a calibrated activation scale, exactly like
    any production int workflow. Modes:

    * ``"max"`` — scale so max|x| hits the grid edge 2^int_bits (classic
      absmax calibration; keeps integer parts informative).
    * ``"rms"`` — scale so rms(x) = 2^(int_bits-2) (outlier-robust).
    * ``"none"`` — identity (paper-literal: values used as-is).

    Scores computed on scaled tensors are divided by s_q*s_k afterwards,
    so calibration changes only the quantization grid, never the
    attention semantics.
    """
    if mode == "none":
        return jnp.ones((), jnp.float32)
    xf = x.astype(jnp.float32)
    if mode == "max":
        m = jnp.max(jnp.abs(xf))
        return (2.0 ** int_bits) * 0.999 / jnp.maximum(m, 1e-6)
    if mode == "rms":
        r = jnp.sqrt(jnp.mean(jnp.square(xf)))
        return (2.0 ** max(int_bits - 2, 0)) / jnp.maximum(r, 1e-6)
    raise ValueError(f"unknown calibration mode {mode!r}")
