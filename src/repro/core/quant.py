"""Fixed-point quantization and integer/fraction split (paper Sec. III).

The paper assumes Q/K/V arrive quantized in 16-bit fixed point and bases
every pruning decision on the *integer parts* only. We keep values in float
containers but snap them to the fixed-point grid, so the integer/fractional
decomposition and the scout matmul are exact (int32-representable).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_fixed(x: jnp.ndarray, int_bits: int = 4, frac_bits: int = 12) -> jnp.ndarray:
    """Quantize to signed fixed point Q(int_bits).(frac_bits).

    Range is [-2^int_bits, 2^int_bits - 2^-frac_bits]; resolution 2^-frac_bits.
    Returned values live on the grid but keep x.dtype (float) so downstream
    matmuls stay on the MXU.
    """
    scale = jnp.asarray(2.0**frac_bits, x.dtype)
    lo = -(2.0**int_bits)
    hi = 2.0**int_bits - 2.0 ** (-frac_bits)
    q = jnp.round(x * scale) / scale
    return jnp.clip(q, lo, hi)


def int_frac_split(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split into integer part (trunc toward zero) and fractional remainder.

    x == I + F with I integer-valued and F in (-1, 1). Near-zero values
    (|x| < 1) have I == 0 — this is what gives the paper's free near-zero
    pruning when the F*F term is dropped.
    """
    i = jnp.trunc(x)
    return i, x - i


def quantize_and_split(
    x: jnp.ndarray, int_bits: int = 4, frac_bits: int = 12
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """quantize_fixed followed by int_frac_split; returns (xq, I, F)."""
    xq = quantize_fixed(x, int_bits, frac_bits)
    i, f = int_frac_split(xq)
    return xq, i, f


def calib_scale(x: jnp.ndarray, int_bits: int, mode: str) -> jnp.ndarray:
    """Per-tensor scale mapping x onto the fixed-point grid.

    The paper's co-processor receives Q/K/V already quantized by the host
    accelerator — i.e. with a calibrated activation scale, exactly like
    any production int workflow. Modes:

    * ``"max"`` — scale so max|x| hits the grid edge 2^int_bits (classic
      absmax calibration; keeps integer parts informative).
    * ``"rms"`` — scale so rms(x) = 2^(int_bits-2) (outlier-robust).
    * ``"none"`` — identity (paper-literal: values used as-is).

    Scores computed on scaled tensors are divided by s_q*s_k afterwards,
    so calibration changes only the quantization grid, never the
    attention semantics.
    """
    if mode == "none":
        return jnp.ones((), jnp.float32)
    xf = x.astype(jnp.float32)
    if mode == "max":
        m = jnp.max(jnp.abs(xf))
        return (2.0 ** int_bits) * 0.999 / jnp.maximum(m, 1e-6)
    if mode == "rms":
        r = jnp.sqrt(jnp.mean(jnp.square(xf)))
        return (2.0 ** max(int_bits - 2, 0)) / jnp.maximum(r, 1e-6)
    raise ValueError(f"unknown calibration mode {mode!r}")
