"""Block importance, row-balanced thresholds and masks (Alg. 2 lines 6-17).

Everything here operates on arbitrarily-batched score maps [..., Lq, Lk];
block geometry is static. `valid` masks let the same math serve causal LMs
(future blocks are excluded from min/max/mean and never counted as pruned —
a TPU adaptation documented in DESIGN.md; the paper is encoder-only).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

_NEG = -1e30  # used instead of -inf to keep masked softmax NaN-free


def block_abs_sum(scores: jnp.ndarray, block_q: int, block_k: int) -> jnp.ndarray:
    """theta_j = sum |x| over each block  ->  [..., Lq/bq, Lk/bk]."""
    *lead, lq, lk = scores.shape
    if lq % block_q or lk % block_k:
        raise ValueError(f"({lq},{lk}) not divisible by block ({block_q},{block_k})")
    r = scores.reshape(*lead, lq // block_q, block_q, lk // block_k, block_k)
    return jnp.abs(r).sum(axis=(-3, -1))


def block_sum(scores: jnp.ndarray, block_q: int, block_k: int) -> jnp.ndarray:
    """Plain block sum (used by near-zero statistics)."""
    *lead, lq, lk = scores.shape
    r = scores.reshape(*lead, lq // block_q, block_q, lk // block_k, block_k)
    return r.sum(axis=(-3, -1))


def pooled_block_theta(
    scores: jnp.ndarray, valid: jnp.ndarray, block_k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pool a [..., q, Sk] score slab into ONE row of Sk/block_k blocks.

    The whole q extent is treated as a single block row (decode-shaped
    pooling: with a block-paged KV cache these blocks ARE the cache
    pages). ``valid`` is a positionally-broadcastable bool mask over
    [..., q, Sk]. Returns (theta [..., nk] f32 abs-sum importances,
    bvalid [..., nk] blocks with any valid position).
    """
    s = jnp.where(valid, scores, 0.0)
    *lead, q, sk = s.shape
    theta = jnp.abs(s.reshape(*lead, q, sk // block_k, block_k)).sum(
        axis=(-3, -1))
    *vlead, vq, _ = valid.shape
    bvalid = valid.reshape(*vlead, vq, sk // block_k, block_k).any(
        axis=(-3, -1))
    return theta, bvalid


def row_threshold(
    theta: jnp.ndarray, rho_b, valid: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Theta_i per row of blocks (Alg. 2 line 15), both rho_B branches.

    theta: [..., R, C]; valid: optional bool [..., R, C] marking blocks that
    participate in the statistics. Returns [..., R, 1].
    """
    rho = jnp.asarray(rho_b, theta.dtype)
    if valid is None:
        tmin = theta.min(axis=-1, keepdims=True)
        tmax = theta.max(axis=-1, keepdims=True)
        tmean = theta.mean(axis=-1, keepdims=True)
    else:
        big = jnp.asarray(jnp.finfo(theta.dtype).max, theta.dtype)
        tmin = jnp.where(valid, theta, big).min(axis=-1, keepdims=True)
        tmax = jnp.where(valid, theta, -big).max(axis=-1, keepdims=True)
        cnt = valid.sum(axis=-1, keepdims=True).astype(theta.dtype)
        cnt = jnp.maximum(cnt, 1.0)
        tmean = jnp.where(valid, theta, 0.0).sum(axis=-1, keepdims=True) / cnt
    pos = rho * tmax + (1.0 - rho) * tmean
    neg = -rho * tmin + (1.0 + rho) * tmean
    return jnp.where(rho >= 0, pos, neg)


def block_keep_mask(
    theta: jnp.ndarray, threshold: jnp.ndarray, valid: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mask_i^j = 0 iff theta_j < Theta_i (Alg. 2 line 16); bool keep mask."""
    keep = theta >= threshold
    if valid is not None:
        keep = jnp.logical_and(keep, valid)
    return keep


def expand_block_mask(
    mask: jnp.ndarray, block_q: int, block_k: int
) -> jnp.ndarray:
    """[..., R, C] block mask -> [..., R*bq, C*bk] element mask."""
    m = jnp.repeat(mask, block_q, axis=-2)
    return jnp.repeat(m, block_k, axis=-1)


def causal_block_valid(
    lq: int, lk: int, block_q: int, block_k: int, q_offset: int = 0
) -> jnp.ndarray:
    """Blocks with at least one causally-visible (q >= k) entry.

    q_offset shifts query positions (decode: q_offset = cache_len).
    Returns bool [lq/bq, lk/bk].
    """
    qb = jnp.arange(lq // block_q) * block_q + (block_q - 1) + q_offset  # last q row of block
    kb = jnp.arange(lk // block_k) * block_k  # first k col of block
    return qb[:, None] >= kb[None, :]


def causal_element_mask(lq: int, lk: int, q_offset: int = 0) -> jnp.ndarray:
    q = jnp.arange(lq) + q_offset
    k = jnp.arange(lk)
    return q[:, None] >= k[None, :]


def apply_score_mask(scores: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Exclusion semantics: pruned entries leave the softmax entirely."""
    return jnp.where(keep, scores, jnp.asarray(_NEG, scores.dtype))


def masked_softmax(scores: jnp.ndarray, keep: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Row softmax with exclusion; fully-pruned rows produce zeros."""
    if keep is not None:
        scores = apply_score_mask(scores, keep)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    if keep is not None:
        e = jnp.where(keep, e, 0.0)
    s = e.sum(axis=-1, keepdims=True)
    return e / jnp.maximum(s, jnp.asarray(1e-30, scores.dtype))


# ---------------------------------------------------------------------------
# ASIC-faithful polynomial softmax (paper Sec. IV-E): 2nd-order polynomial
# exponent with range reduction + linear-approximation reciprocal.
# ---------------------------------------------------------------------------

_LN2 = 0.6931471805599453


def poly_exp(x: jnp.ndarray) -> jnp.ndarray:
    """I-BERT-style 2nd-order polynomial exp for x <= 0.

    e^x = 2^(-z) * e^r with r in (-ln2, 0];  e^r ~ 0.3585 (r+1.353)^2 + 0.344.
    """
    x = jnp.minimum(x, 0.0)
    z = jnp.floor(-x / _LN2)
    r = x + z * _LN2
    p = 0.3585 * (r + 1.353) ** 2 + 0.344
    return p * jnp.exp2(-z)


def linear_reciprocal(s: jnp.ndarray, newton_iters: int = 2) -> jnp.ndarray:
    """Reciprocal via linear approximation on the mantissa + Newton steps.

    For s = m * 2^e with m in [1, 2): 1/m ~ 24/17 - 8/17*m (the classical
    Newton-Raphson division seed rescaled to [1,2)), refined by Newton
    iterations y <- y * (2 - s*y) — matching a cheap fixed-point divider.
    """
    s = jnp.maximum(s, 1e-30)
    e = jnp.floor(jnp.log2(s))
    m = s * jnp.exp2(-e)
    y = (24.0 / 17.0 - 8.0 / 17.0 * m) * jnp.exp2(-e)
    for _ in range(newton_iters):
        y = y * (2.0 - s * y)
    return y


def approx_softmax(scores: jnp.ndarray, keep: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Softmax as the HDP softmax unit computes it (poly exp + lin recip)."""
    if keep is not None:
        scores = apply_score_mask(scores, keep)
    m = scores.max(axis=-1, keepdims=True)
    e = poly_exp(scores - m)
    if keep is not None:
        e = jnp.where(keep, e, 0.0)
    s = e.sum(axis=-1, keepdims=True)
    return e * linear_reciprocal(s)


def net_sparsity(
    keep_blocks: jnp.ndarray,
    head_kept: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(block_sparsity_in_kept_heads, head_sparsity, net_sparsity).

    Net sparsity counts a block as skipped if its head was pruned OR the
    block itself was pruned — the paper's Fig. 10 accounting. All fractions
    are over *valid* (causally reachable) blocks.
    """
    kb = keep_blocks.astype(jnp.float32)
    hk = head_kept.astype(jnp.float32)  # [..., 1, 1]-broadcastable
    if valid is None:
        valid_f = jnp.ones_like(kb)
    else:
        valid_f = valid.astype(jnp.float32) * jnp.ones_like(kb)
    total = jnp.maximum(valid_f.sum(), 1.0)
    kept_and_head = kb * hk * valid_f
    block_pruned = (valid_f - kb * valid_f) * hk
    head_pruned = valid_f * (1.0 - hk)
    block_sp = block_pruned.sum() / jnp.maximum((valid_f * hk).sum(), 1.0)
    head_sp = head_pruned.sum() / total
    net = 1.0 - kept_and_head.sum() / total
    return block_sp, head_sp, net
