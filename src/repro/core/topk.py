"""Top-K block pruning baseline (paper Sec. V-A2(a), Fig. 7).

The paper's comparison oracle: per row of blocks, keep exactly the top-k
blocks by full-precision importance. HDP's threshold rule approximates this
without sorting hardware; the Fig. 7 analog benchmark measures how well.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import blocking


def topk_block_mask(
    scores: jnp.ndarray,
    block_q: int,
    block_k: int,
    keep_ratio: float,
    valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Keep the top ceil(keep_ratio * C) blocks per block-row.

    scores: full-precision attention scores [..., Lq, Lk].
    Returns bool keep mask on block geometry [..., R, C].
    """
    theta = blocking.block_abs_sum(scores, block_q, block_k)
    c = theta.shape[-1]
    k = max(1, int(round(keep_ratio * c)))
    if valid is not None:
        theta = jnp.where(valid, theta, -jnp.inf)
    # threshold = k-th largest per row
    kth = jnp.sort(theta, axis=-1)[..., c - k : c - k + 1]
    keep = theta >= kth
    if valid is not None:
        keep = jnp.logical_and(keep, valid)
    return keep


def topk_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    block_q: int, block_k: int, keep_ratio: float,
    *, causal: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact attention with Top-K block pruning; returns (out, keep_blocks)."""
    scores = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(
        jnp.asarray(q.shape[-1], q.dtype))
    valid = None
    if causal:
        valid = blocking.causal_block_valid(q.shape[-2], k.shape[-2], block_q, block_k)
    keep = topk_block_mask(scores, block_q, block_k, keep_ratio, valid)
    keep_elem = blocking.expand_block_mask(keep, block_q, block_k)
    if causal:
        keep_elem = jnp.logical_and(
            keep_elem,
            blocking.causal_element_mask(q.shape[-2], k.shape[-2]))
    prob = blocking.masked_softmax(scores, keep_elem)
    return jnp.einsum("...qk,...kd->...qd", prob, v), keep


def mask_agreement(mask_a: jnp.ndarray, mask_b: jnp.ndarray) -> jnp.ndarray:
    """IoU of two keep masks — the Fig. 7 'does HDP track Top-K' metric."""
    a = mask_a.astype(jnp.float32)
    b = mask_b.astype(jnp.float32)
    inter = (a * b).sum()
    union = jnp.maximum((jnp.maximum(a, b)).sum(), 1.0)
    return inter / union
