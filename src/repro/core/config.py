"""HDP configuration.

All knobs of the paper's Algorithm 2 plus the TPU-adaptation switches.
Defaults mirror the paper: 16-bit fixed point (4 integer + 12 fractional
bits), 2x2 blocks, both rho_B branches supported, approximation on.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HDPConfig:
    """Configuration for Hybrid Dynamic Pruning attention.

    Attributes:
      enabled: master switch; False -> exact dense attention.
      rho_b: block pruning ratio in (-1, 1). Algorithm 2 line 15:
        Theta = rho*max + (1-rho)*mean      if rho in [0, 1)
        Theta = -rho*min + (1+rho)*mean     if rho in (-1, 0)
      tau_h: head pruning threshold; heads with theta_head <= tau_h are
        pruned entirely (output zeroed, downstream compute skipped).
      block_q / block_k: pruning-block size. The paper's ASIC uses 2x2;
        the Pallas kernel path requires TPU-aligned blocks (>= 8x128).
      int_bits / frac_bits: fixed-point format of the quantizer.
      approx: drop the FQ*FK^T term (paper Sec III-B). False computes the
        exact product of the quantized inputs.
      block_pruning / head_pruning: enable the individual mechanisms.
      normalize_head_score: divide theta_head by the number of valid score
        entries so tau_h is sequence-length independent (TPU adaptation;
        the paper profiles raw sums per model/seq-len).
      approx_softmax: use the ASIC-faithful 2nd-order polynomial exp +
        linear-approximation reciprocal instead of exact softmax.
      causal: compose the HDP mask with a causal mask and exclude fully
        future blocks from row statistics (TPU adaptation for decoder LMs;
        the paper evaluates encoder-only models).
    """

    enabled: bool = True
    rho_b: float = 0.5
    tau_h: float = 0.0
    block_q: int = 2
    block_k: int = 2
    int_bits: int = 4
    frac_bits: int = 12
    # activation-scale calibration for the fixed-point grid ("max" | "rms"
    # | "none"). The paper's co-processor receives Q/K pre-quantized by the
    # host accelerator, i.e. with a calibrated scale; "none" reproduces the
    # raw-value behaviour. Scores are rescaled by 1/(s_q*s_k) afterwards,
    # so calibration changes only integer-part informativeness, never the
    # attention semantics.
    calib: str = "max"
    approx: bool = True
    block_pruning: bool = True
    head_pruning: bool = True
    normalize_head_score: bool = False
    approx_softmax: bool = False
    causal: bool = False
    # HDP is an inference-time technique (no retraining needed). The paper's
    # Sec. V-B fine-tunes *with* pruning active for the SpAtten comparison;
    # setting this replicates that mode in train_step.
    apply_in_training: bool = False

    def __post_init__(self):
        if not (-1.0 < self.rho_b < 1.0):
            raise ValueError(f"rho_b must be in (-1, 1), got {self.rho_b}")
        if self.block_q < 1 or self.block_k < 1:
            raise ValueError("block sizes must be >= 1")
        if self.int_bits < 1 or self.frac_bits < 0:
            raise ValueError("need int_bits >= 1, frac_bits >= 0")

    def replace(self, **kw) -> "HDPConfig":
        return dataclasses.replace(self, **kw)


#: Paper's ASIC configuration (Sec. V): 2x2 blocks, 16-bit fixed point.
PAPER_ASIC = HDPConfig(block_q=2, block_k=2, int_bits=4, frac_bits=12)

#: TPU-native kernel configuration: pruning block == DMA/MXU tile.
TPU_KERNEL = HDPConfig(block_q=128, block_k=128, int_bits=4, frac_bits=12,
                       normalize_head_score=True, causal=True)
