"""HDP core: the paper's contribution as a composable JAX module."""
from repro.core.config import HDPConfig, PAPER_ASIC, TPU_KERNEL
from repro.core.hdp import (
    HDPStats,
    dense_attention_reference,
    hdp_attention,
    hdp_attention_reference,
)
from repro.core.quant import int_frac_split, quantize_and_split, quantize_fixed
from repro.core.topk import mask_agreement, topk_attention, topk_block_mask

__all__ = [
    "HDPConfig", "PAPER_ASIC", "TPU_KERNEL", "HDPStats",
    "hdp_attention", "hdp_attention_reference", "dense_attention_reference",
    "quantize_fixed", "int_frac_split", "quantize_and_split",
    "topk_block_mask", "topk_attention", "mask_agreement",
]
