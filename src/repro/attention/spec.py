"""Attention call descriptors and backend-selection specs.

``AttnCall`` is the frozen, hashable descriptor of ONE attention
invocation — everything a backend needs to decide *whether* it can serve
the call (``Backend.supports``) and *how* (mask semantics, HDP pipeline
on/off, cache layout). Runtime tensors (position arrays, page tables,
page pools) are deliberately NOT part of the call: they are passed
alongside to :func:`repro.attention.attention` so the descriptor stays
static under ``jax.jit`` tracing. The paper-level knobs named in the
design (q_offset / kv_len) are generalized here to the ``q_pos`` /
``k_pos`` position arrays every implementation already masks with.

``AttnSpec`` is the user-facing selection policy threaded through the
model / serving layers instead of the former stringly-typed
``attn_backend=`` / ``cache_backend=`` kwargs: an exact backend name, a
family tag ("xla" | "pallas" | "reference"), or "auto", with optional
per-mode overrides plus the serving cache layout. The old string kwargs
keep working for one release via :func:`spec_from_legacy`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.config import HDPConfig

MODES = ("prefill", "decode")
LAYOUTS = ("dense", "paged")
CACHE_LAYOUTS = ("auto", "dense", "paged")
DRAFT_SCORES = ("scout", "int", "approx")
POLICIES = ("auto", "static", "cost")
KV_DTYPES = ("auto", "fp32", "int8", "fp8_v")
KV_SCALES = ("grid", "absmax")


@dataclasses.dataclass(frozen=True)
class DraftProfile:
    """Approximate-attention overlay for the self-speculative draft pass.

    The draft runs the same transformer with a cheaper attention step and
    proposes tokens that a full-fidelity verify pass then accepts or
    rejects — so the profile only trades *acceptance rate* against *draft
    cost*, never output correctness (exact-match acceptance keeps the
    committed tokens identical to non-speculative greedy decode).

    Attributes:
      rho_b / tau_h: optional overrides of the HDP survival thresholds —
        a more aggressive grid than the exact pass (fewer blocks/heads
        survive, so the draft fetches less KV memory).
      scores: score source of the draft attention:
        * ``"scout"`` — ``QQ·IK + IQ·FK^`` over the two int8 scout
          copies of K (the integer copy the decode scout always streams,
          plus a write-time quantized-fraction copy): recovers the exact
          pass's approximate scores to within the 2^-6 fraction grid,
          and the full-precision K of the cache is never read by a
          draft step. The default — near-exact proposals at int8
          bandwidth.
        * ``"int"`` — the scout matmul itself (``IQ·IK``, integer parts
          only) reused as the score; the cheapest draft, no extra matmul.
        * ``"approx"`` — the exact pass's ``QQ·KQ - FQ·FK``; the draft
          is then a pruning-only approximation (thresholds overrides do
          all the work).
    """

    rho_b: Optional[float] = None
    tau_h: Optional[float] = None
    scores: str = "scout"

    def __post_init__(self):
        if self.scores not in DRAFT_SCORES:
            raise ValueError(
                f"draft scores must be one of {DRAFT_SCORES}, "
                f"got {self.scores!r}")
        if self.rho_b is not None and not (-1.0 < self.rho_b < 1.0):
            raise ValueError(f"draft rho_b must be in (-1, 1), got {self.rho_b}")

    def overlay(self, hdp: HDPConfig) -> HDPConfig:
        """HDP config the draft attends with (threshold overrides applied)."""
        kw = {}
        if self.rho_b is not None:
            kw["rho_b"] = self.rho_b
        if self.tau_h is not None:
            kw["tau_h"] = self.tau_h
        return hdp.replace(**kw) if kw else hdp


@dataclasses.dataclass(frozen=True)
class AttnCall:
    """Static descriptor of one attention invocation.

    Attributes:
      mode: "prefill" (train and prompt runs) | "decode" (query vs cache).
      layout: "dense" contiguous K/V tensors | "paged" block-paged pools
        (cache dict with ``k_pages``/``v_pages``[/``k_scout``] + table).
      causal: compose a causal mask from the q/k position arrays.
      window: sliding-window width (0 = unbounded).
      hdp: the HDP pipeline config, or None for exact dense attention
        (``enabled=False`` configs are normalized to None at build time).
      per_slot: positions carry a batch dim (continuous-batching decode).
      self_aligned: q spans the whole KV extent from position 0 with
        shared positions (no cache, no cross) — the shape contract the
        monolithic Pallas kernels require.
      trainable: gradients must flow (train step); excludes backends
        without a VJP (the Pallas kernels).
      chunk: KV chunk length hint for flash-style scanning (0 = whole
        extent); a perf knob, never a semantic one.
      needs_stats: backend should return populated AttnStats.
      draft: self-speculative draft overlay (``hdp`` already carries the
        overlaid thresholds; this selects the draft score source), or
        None for a full-fidelity call. Only meaningful with HDP active —
        without a scout there is no approximate path to draft with.
      kv_scale: scale grid of the quantized pool — "grid" (static
        power-of-two step) or "absmax" (per-page calibrated scales; the
        stage-3 dequant must then read the pool's scale arrays).
      verify: multi-query decode (Sq > 1 query rows over one cache, the
        speculative verify shape). HDP backends must then run the scout
        *per query row* — each row's keep mask / head gate must equal
        what its own single-token decode step would compute, or
        exact-match acceptance loses token identity. Verify rows sit at
        consecutive positions (row j's KV extent is row 0's plus j).
    """

    mode: str
    layout: str = "dense"
    causal: bool = True
    window: int = 0
    hdp: Optional[HDPConfig] = None
    per_slot: bool = False
    self_aligned: bool = False
    trainable: bool = False
    chunk: int = 0
    needs_stats: bool = False
    draft: Optional[DraftProfile] = None
    verify: bool = False
    kv_scale: str = "grid"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.kv_scale not in KV_SCALES:
            raise ValueError(
                f"kv_scale must be one of {KV_SCALES}, got {self.kv_scale!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"layout must be one of {LAYOUTS}, got {self.layout!r}")
        if self.layout == "paged" and self.mode != "decode":
            raise ValueError("paged layout is a decode-time serving format")
        if (self.draft is not None or self.verify) and self.mode != "decode":
            raise ValueError("draft/verify are decode-time call shapes")
        if self.hdp is not None and not self.hdp.enabled:
            object.__setattr__(self, "hdp", None)
        if self.hdp is None:
            # no scout => nothing to approximate; a draft call degenerates
            # to the exact attention step (still a valid token proposer)
            object.__setattr__(self, "draft", None)

    def replace(self, **kw) -> "AttnCall":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Backend-selection policy threaded through models / serving.

    Attributes:
      backend: exact backend name (``"xla_hdp"``), family tag (``"xla"``,
        ``"pallas"``, ``"reference"``), or ``"auto"`` (highest-ranked
        supporting backend; Pallas ranks above XLA only on TPU).
      prefill / decode: optional per-mode overrides of ``backend``.
      layout: serving cache layout — "auto" picks paged for transformer
        families, dense otherwise (Engine-level; ignored by dispatch).
      kv_dtype: storage format of the paged KV pool — "int8" (the
        production default: per-page scales, scout copies derived as
        views), "fp8_v" (int8 K + fp8 V), or "fp32" (the opt-in A/B
        oracle). "auto" (default) resolves through ``REPRO_KV_DTYPE``
        then "int8". Quantized-pool engines round-trip K/V through the
        pool grid at *prefill* write time (so prefix hits, COW tails and
        chunked prefill stay token-identical to cold runs); dense-layout
        engines always serve fp32.
      kv_scale: scale calibration of the quantized pool — "grid" (the
        default static power-of-two step; bit-parity guarantees hold) or
        "absmax" (opt-in per-page calibrated absmax scales: lower
        round-trip error, but prefill values are no longer snapped to a
        known grid, so hot/cold bit parity is forfeited and the fp32
        A/B drift gate is the accuracy contract instead).
      allow_fallback: when the requested backend does not support a call,
        fall down the auto chain instead of raising.
      policy: how "auto" picks among supporting candidates —
        * ``"static"``: registry priority order (the historical rule).
        * ``"cost"``: the :mod:`repro.autotune` cost model ranks the
          candidates under the detected hardware profile, probing
          ambiguous calls once. Only consulted when the *requested*
          backend resolves to "auto" — an exact name or family tag still
          pins.
        * ``"auto"`` (default): ``REPRO_ATTN_POLICY`` decides (``cost``
          enables the tuner, anything else means static).
    """

    backend: str = "auto"
    prefill: Optional[str] = None
    decode: Optional[str] = None
    layout: str = "auto"
    kv_dtype: str = "auto"
    kv_scale: str = "grid"
    allow_fallback: bool = True
    policy: str = "auto"

    def __post_init__(self):
        if self.layout not in CACHE_LAYOUTS:
            raise ValueError(
                f"layout must be one of {CACHE_LAYOUTS}, got {self.layout!r}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {self.kv_dtype!r}")
        if self.kv_scale not in KV_SCALES:
            raise ValueError(
                f"kv_scale must be one of {KV_SCALES}, got {self.kv_scale!r}")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy must be one of {POLICIES}, got {self.policy!r}")

    def requested_for(self, mode: str) -> str:
        over = self.prefill if mode == "prefill" else self.decode
        return over if over is not None else self.backend

    def replace(self, **kw) -> "AttnSpec":
        return dataclasses.replace(self, **kw)


_LEGACY_ATTN = {"xla": "xla", "pallas": "pallas", "auto": "auto"}


def spec_from_legacy(attn_backend: Optional[str] = None,
                     cache_backend: Optional[str] = None,
                     base: Optional[AttnSpec] = None,
                     stacklevel: int = 3) -> AttnSpec:
    """Map the deprecated string kwargs onto an :class:`AttnSpec`.

    Emits ONE DeprecationWarning covering every legacy kwarg passed.
    Removal is scheduled for the release after the registry lands.
    """
    spec = base if base is not None else AttnSpec()
    legacy = []
    if attn_backend is not None:
        if attn_backend not in _LEGACY_ATTN:
            raise ValueError(f"unknown attn_backend {attn_backend!r}")
        legacy.append(f"attn_backend={attn_backend!r}")
        spec = spec.replace(backend=_LEGACY_ATTN[attn_backend])
    if cache_backend is not None:
        if cache_backend not in CACHE_LAYOUTS:
            raise ValueError(f"unknown cache_backend {cache_backend!r}")
        legacy.append(f"cache_backend={cache_backend!r}")
        spec = spec.replace(layout=cache_backend)
    if legacy:
        warnings.warn(
            f"{', '.join(legacy)} string kwargs are deprecated; pass "
            f"attn=AttnSpec(backend={spec.backend!r}, layout={spec.layout!r}) "
            "instead (repro.attention.AttnSpec)",
            DeprecationWarning, stacklevel=stacklevel)
    return spec
