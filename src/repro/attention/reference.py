"""The ``reference`` backend: materializing oracle for every call shape.

Generalizes ``core.hdp.hdp_attention_reference`` (the paper's Algorithm 2
transliteration, single-head [..., L, d]) to the model tensor layout
(q [B,N,G,Sq,hd]; k/v [B,Sk,N,hd]) and to every call the registry can
describe: prefill and decode, dense and paged layouts, causal/window
masks, per-slot positions, HDP on or off. Everything is computed densely
with explicit masks — no scans, no kernels, no fetch-upon-mask gather —
so it is the conformance ground truth each production backend is tested
against, and the slowest-but-safest fallback of the auto chain.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.attention.registry import register_backend
from repro.attention.spec import AttnCall
from repro.attention.stats import AttnStats
from repro.core import blocking
from repro.core.hdp import calibrated_split, decode_scout

F32 = jnp.float32


def _supports(call: AttnCall) -> bool:
    del call
    return True  # the oracle serves every valid AttnCall


def _pad_axis(x, axis, target):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_pos(pos, target):
    """Pad a position array along its last axis; pads become -1 (invalid)."""
    return _pad_axis(pos + 1, pos.ndim - 1, target) - 1


def _densify(cache, page_table, int_bits=4):
    """Gather the FULL page pools into contiguous [B, nP*ps, N, hd] tensors.

    The oracle reads everything — fetch-upon-mask is a performance
    property of the production backends, not part of the semantics; the
    keep mask excludes pruned pages from the softmax either way.
    ``int_bits`` is the pool grid of a quantized cache (ignored for fp32
    pools): it fixes the static scale of the derived integer scout view.
    """
    kp, vp = cache["k_pages"], cache["v_pages"]
    B, nP = page_table.shape
    ps, N, hd = kp.shape[1], kp.shape[2], kp.shape[3]
    if kp.dtype == jnp.int8:
        # quantized pool: dequantize through the per-page scales (poison
        # sentinel -> NaN, exactly like the production stage 3) and
        # derive the integer scout view from the codes (poison -> 0,
        # exactly like the production stage 1)
        from repro.core.quant import decode_pool, pool_view_finite
        ks = cache["k_scale"][page_table][:, :, None, :, None]
        vs = cache["v_scale"][page_table][:, :, None, :, None]
        k = decode_pool(kp[page_table], ks).reshape(B, nP * ps, N, hd)
        vg = vp[page_table]
        v = (vg.astype(F32) * vs if vg.dtype != jnp.int8
             else decode_pool(vg, vs)).reshape(B, nP * ps, N, hd)
        ik = jnp.trunc(pool_view_finite(kp[page_table], int_bits).reshape(
            B, nP * ps, N, hd))
        return k, v, ik
    k = kp[page_table].reshape(B, nP * ps, N, hd)
    v = vp[page_table].reshape(B, nP * ps, N, hd)
    ik = None
    if "k_scout" in cache:
        ik = cache["k_scout"][page_table].reshape(B, nP * ps, N, hd).astype(F32)
    return k, v, ik


def _sparsity_stats(keep, bvalid, head_kept):
    kept = (keep & bvalid).astype(F32).sum()
    tot = jnp.maximum(
        jnp.broadcast_to(bvalid, keep.shape).astype(F32).sum(), 1.0)
    return (1.0 - kept / tot, 1.0 - head_kept.astype(F32).mean())


def _sparsity_stats_per_slot(keep, bvalid, head_kept):
    """Decode-mode stats keep the batch dim ([B] leaves), mirroring the
    production backends, so the serving engine can mask parked slots."""
    ax = tuple(range(1, keep.ndim))
    kept = (keep & bvalid).astype(F32).sum(ax)
    tot = jnp.maximum(
        jnp.broadcast_to(bvalid, keep.shape).astype(F32).sum(ax), 1.0)
    hax = tuple(range(1, head_kept.ndim))
    return (1.0 - kept / tot, 1.0 - head_kept.astype(F32).mean(hax))


def _dense_exact(q, k, v, valid):
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bngqh,bsnh->bngqs", q.astype(F32), k.astype(F32),
                   preferred_element_type=F32) * scale
    p = blocking.masked_softmax(s, valid)
    return jnp.einsum("bngqs,bsnh->bngqh", p, v.astype(F32),
                      preferred_element_type=F32)


def _hdp_prefill(q, k, v, call, q_pos, k_pos):
    """Blockwise scout on the (bq x bk) grid — Algorithm 2, fully dense."""
    hdp = call.hdp
    B, N, G, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = hdp.block_q, hdp.block_k
    Sqp, Skp = _ceil_to(Sq, bq), _ceil_to(Sk, bk)
    scale = 1.0 / (hd ** 0.5)

    sq, qq, iq, fq = calibrated_split(_pad_axis(q, 3, Sqp).astype(F32), hdp)
    sk, kq, ik, fk = calibrated_split(_pad_axis(k, 1, Skp).astype(F32), hdp)
    vp = _pad_axis(v, 1, Skp)
    from repro.models.attention import _mask_bias
    valid = _mask_bias(_pad_pos(q_pos, Sqp), _pad_pos(k_pos, Skp),
                       call.causal, call.window)

    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik,
                       preferred_element_type=F32)
    theta = blocking.block_abs_sum(jnp.where(valid, s_int, 0.0), bq, bk)
    bvalid = blocking.block_abs_sum(valid.astype(F32), bq, bk) > 0
    if hdp.block_pruning:
        thr = blocking.row_threshold(theta, hdp.rho_b, bvalid)
        keep = blocking.block_keep_mask(theta, thr, bvalid)
    else:
        keep = jnp.broadcast_to(bvalid, theta.shape)

    theta_head = jnp.where(bvalid, theta, 0.0).sum(axis=(-2, -1))
    if hdp.normalize_head_score:
        n_valid = valid.astype(F32).sum(axis=(-2, -1))
        theta_head = theta_head / jnp.maximum(n_valid, 1.0)
    head_kept = (theta_head > hdp.tau_h) if hdp.head_pruning \
        else jnp.ones_like(theta_head, bool)

    s = jnp.einsum("bngqh,bsnh->bngqs", qq, kq, preferred_element_type=F32)
    if hdp.approx:
        s = s - jnp.einsum("bngqh,bsnh->bngqs", fq, fk,
                           preferred_element_type=F32)
    s = s * (scale / (sq * sk))
    keep_e = blocking.expand_block_mask(keep, bq, bk) & valid
    softmax = (blocking.approx_softmax if hdp.approx_softmax
               else blocking.masked_softmax)
    p = softmax(s, keep_e)
    out = jnp.einsum("bngqs,bsnh->bngqh", p, vp.astype(F32),
                     preferred_element_type=F32)
    out = out[:, :, :, :Sq] * head_kept[..., None, None].astype(F32)

    stats = None
    if call.needs_stats:
        bs, hs = _sparsity_stats(keep, bvalid, head_kept)
        stats = AttnStats(bs, hs, theta_head=theta_head)
    return out, stats


def _hdp_decode(q, k, v, call, q_pos, k_pos, *, ik=None, fixed_grid=False,
                page_table=None):
    """Pooled-row scout over KV blocks/pages (decode_scout semantics).

    ``ik``: pre-quantized integer scout copy of K (paged: stored at cache
    write time); ``fixed_grid`` selects the calibration-free fixed-point
    split the paged backends always operate on. Verify calls
    (``call.verify``) scout per query row; draft calls (``call.draft``)
    switch the score source to the profile's draft approximation — the
    oracle mirrors the production draft semantics exactly, so draft
    conformance is testable backend-to-backend.
    """
    from repro.models.attention import (_expand_keep, _fixed_split,
                                        _head_gate, _mask_bias)
    hdp = call.hdp
    bk = hdp.block_k
    Sk = k.shape[1]
    Skp = _ceil_to(Sk, bk)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    kp = _pad_axis(k, 1, Skp).astype(F32)
    if fixed_grid:
        qq, iq, fq = _fixed_split(q, hdp)
        kq, _, fk = _fixed_split(kp, hdp)
        rescale = 1.0
    else:
        sq, qq, iq, fq = calibrated_split(q.astype(F32), hdp)
        sk, kq, ik_c, fk = calibrated_split(kp, hdp)
        ik = ik_c if ik is None else ik
        rescale = 1.0 / (sq * sk)
    if ik is None:
        ik = _fixed_split(kp, hdp)[1]
    ik = _pad_axis(ik, 1, Skp)
    vp = _pad_axis(v, 1, Skp)

    valid = _mask_bias(q_pos, _pad_pos(k_pos, Skp), call.causal, call.window)
    s_int = jnp.einsum("bngqh,bsnh->bngqs", iq, ik,
                       preferred_element_type=F32)
    keep, bvalid, _, theta_head, head_kept = decode_scout(
        s_int, valid, hdp, per_query=call.verify)

    if call.draft is not None and call.draft.scores != "approx":
        s = s_int
        if call.draft.scores == "scout":
            # QQ·IK + IQ·FK^: the quantized-fraction term re-quantizes FK
            # to the f_scout grid, matching the production pools bit for
            # bit (the write-time copy holds the same rounded values)
            from repro.models.attention import FRAC_SCOUT_SCALE
            fkh = jnp.round(fk * FRAC_SCOUT_SCALE) / FRAC_SCOUT_SCALE
            s = s + jnp.einsum("bngqh,bsnh->bngqs", fq, ik,
                               preferred_element_type=F32) \
                  + jnp.einsum("bngqh,bsnh->bngqs", iq, fkh,
                               preferred_element_type=F32)
    else:
        s = jnp.einsum("bngqh,bsnh->bngqs", qq, kq,
                       preferred_element_type=F32)
        if hdp.approx:
            s = s - jnp.einsum("bngqh,bsnh->bngqs", fq, fk,
                               preferred_element_type=F32)
    s = s * (scale * rescale)
    keep_e = _expand_keep(keep, bk, valid, s.ndim)
    p = blocking.masked_softmax(s, keep_e)
    out = jnp.einsum("bngqs,bsnh->bngqh", p, vp.astype(F32),
                     preferred_element_type=F32)
    out = _head_gate(out, head_kept.astype(F32))

    stats = None
    if call.needs_stats:
        bs, hs = _sparsity_stats_per_slot(keep, bvalid, head_kept)
        page_sp = None
        if page_table is not None:
            fetched = (keep & head_kept[..., None]).any(
                axis=tuple(range(1, keep.ndim - 1)))
            alloc = jnp.maximum((page_table > 0).astype(F32).sum(-1), 1.0)
            page_sp = 1.0 - jnp.minimum(
                (fetched & (page_table > 0)).astype(F32).sum(-1) / alloc, 1.0)
        stats = AttnStats(bs, hs, theta_head=theta_head,
                          page_sparsity=page_sp)
    return out, stats


@register_backend("reference", supports=_supports, priority=0,
                  tags=("reference",))
def run_reference(q, k, v, call: AttnCall, *, q_pos, k_pos, cache=None,
                  page_table=None):
    from repro.models.attention import _mask_bias
    from repro.core.quant import pool_int_bits
    ik = None
    fixed_grid = False
    if call.layout == "paged":
        k, v, ik = _densify(cache, page_table, pool_int_bits(call.hdp))
        fixed_grid = True  # write-time scout copy => static fixed-point grid
    if call.hdp is None:
        valid = _mask_bias(q_pos, k_pos, call.causal, call.window)
        out = _dense_exact(q, k, v, valid)
        return out.astype(q.dtype), None
    if call.mode == "decode":
        out, stats = _hdp_decode(q, k, v, call, q_pos, k_pos, ik=ik,
                                 fixed_grid=fixed_grid,
                                 page_table=page_table)
    else:
        out, stats = _hdp_prefill(q, k, v, call, q_pos, k_pos)
    return out.astype(q.dtype), stats
