"""Unified attention-backend registry (one dispatch layer, six backends).

Public API:

* :class:`AttnCall` — frozen descriptor of one attention invocation.
* :class:`AttnSpec` — backend-selection policy (replaces the deprecated
  ``attn_backend=`` / ``cache_backend=`` string kwargs).
* :func:`attention` — the single dispatch entry:
  ``attention(q, k, v, call, *, spec, q_pos, k_pos, cache, page_table)``
  returns ``(out, AttnStats | None)``.
* :func:`register_backend` / :func:`resolve_backend` /
  :func:`list_backends` — the registry itself.

Backends (see ``backends.py`` / ``reference.py``): ``reference`` (the
materializing oracle), ``xla_dense``, ``xla_hdp``, ``paged_hdp_decode``,
``pallas_flash``, ``pallas_hdp_block``. Auto-selection falls
pallas -> xla -> reference (Pallas only out-ranks XLA on TPU; off-TPU it
runs in interpret mode when explicitly requested).
"""
from repro.attention.registry import (BACKEND_ENV, POLICY_ENV, Backend,
                                      BackendUnsupported, attention,
                                      default_spec, effective_policy,
                                      get_backend, known_backend_names,
                                      list_backends, register_backend,
                                      resolve_backend)
from repro.attention.spec import (AttnCall, AttnSpec, DraftProfile,
                                  spec_from_legacy)
from repro.attention.stats import AttnStats, normalize_stats

__all__ = [
    "AttnCall", "AttnSpec", "AttnStats", "Backend", "BackendUnsupported",
    "BACKEND_ENV", "POLICY_ENV", "DraftProfile", "attention", "default_spec",
    "effective_policy", "get_backend", "known_backend_names", "list_backends",
    "normalize_stats", "register_backend", "resolve_backend",
    "spec_from_legacy",
]
