"""One normalized stats shape for every attention backend.

The pre-registry code emitted three different stats shapes (a frozen
``HDPStats`` dataclass from ``core.hdp``, ad-hoc dicts from the model
paths, another dict from the kernel pipeline). Every registered backend
now returns ``AttnStats | None`` — a registered JAX pytree, so it rides
through ``jax.jit`` / ``lax.scan`` (the per-layer stack in
``transformer._stack``) unchanged. Dict-style access is kept so existing
consumers (``benchmarks/common.py``, examples) keep working.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AttnStats:
    """Diagnostics from one attention call (all jnp arrays or None).

    block_sparsity: scalar pruned-block fraction over valid blocks.
    head_sparsity: scalar pruned-head fraction.
    theta_head: per-head importances [..., heads-shaped] (optional).
    page_sparsity: scalar never-fetched page fraction (paged decode only).
    """

    block_sparsity: jnp.ndarray
    head_sparsity: jnp.ndarray
    theta_head: Optional[jnp.ndarray] = None
    page_sparsity: Optional[jnp.ndarray] = None

    # dict-style compat with the pre-registry stats consumers
    def __getitem__(self, key: str):
        val = getattr(self, key)
        if val is None:
            raise KeyError(key)
        return val

    def get(self, key: str, default=None):
        try:
            return self[key]
        except (KeyError, AttributeError):
            return default

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None


jax.tree_util.register_dataclass(
    AttnStats,
    data_fields=("block_sparsity", "head_sparsity", "theta_head",
                 "page_sparsity"),
    meta_fields=())


def normalize_stats(raw: Any) -> Optional[AttnStats]:
    """Coerce a backend's native stats (dict / HDPStats / None) to AttnStats."""
    if raw is None or isinstance(raw, AttnStats):
        return raw
    if isinstance(raw, Mapping):
        return AttnStats(
            block_sparsity=jnp.asarray(raw["block_sparsity"]),
            head_sparsity=jnp.asarray(raw["head_sparsity"]),
            theta_head=raw.get("theta_head"),
            page_sparsity=raw.get("page_sparsity"))
    # core.hdp.HDPStats-shaped object (attribute access)
    return AttnStats(
        block_sparsity=jnp.asarray(raw.block_sparsity),
        head_sparsity=jnp.asarray(raw.head_sparsity),
        theta_head=getattr(raw, "theta_head", None),
        page_sparsity=getattr(raw, "page_sparsity", None))
