"""Attention-backend registry: declare capabilities, dispatch one entry.

Each backend registers a ``run`` callable plus a ``supports(call)``
capability predicate and a priority; :func:`attention` is the single
dispatch entry the model layer calls. Selection is explicit and
testable:

* ``AttnSpec(backend="auto")`` picks the highest-ranked backend whose
  ``supports(call)`` is True. Pallas backends out-rank XLA only on TPU
  (off-TPU they would run in interpret mode — still selectable
  explicitly, never picked automatically); the ``reference`` oracle
  ranks last, so the fallback chain is pallas -> xla -> reference.
* An exact name (``"pallas_hdp_block"``) or family tag (``"pallas"``)
  requests that implementation; if it cannot serve the call the spec
  either falls down the auto chain (``allow_fallback=True``, the
  default — e.g. the FUM kernel cannot express sliding windows) or
  raises ``BackendUnsupported``.
* ``REPRO_ATTN_BACKEND`` (env) overrides the DEFAULT spec only — calls
  that thread an explicit spec are unaffected. CI uses it to keep the
  oracle path exercised on every PR.

Registering a new backend is one ``@register_backend`` function plus one
row in the conformance matrix (tests/test_attention_registry.py) — the
extension point for the ROADMAP's TPU-native decode work.
"""
from __future__ import annotations

import dataclasses
import importlib
import os
from typing import Callable, Dict, List, Optional

import jax

from repro.attention.spec import AttnCall, AttnSpec

#: env var forcing the *default* spec's backend (explicit specs win).
BACKEND_ENV = "REPRO_ATTN_BACKEND"

#: env var deciding how policy="auto" specs rank auto-selected backends:
#: "cost" routes through the repro.autotune cost model; anything else
#: (including unset) keeps the static priority order.
POLICY_ENV = "REPRO_ATTN_POLICY"

_BACKEND_MODULES = ("repro.attention.reference", "repro.attention.backends")


class BackendUnsupported(ValueError):
    """Requested backend cannot serve the call and fallback is disabled."""


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered attention implementation.

    ``run(q, k, v, call, *, q_pos, k_pos, cache, page_table)`` returns
    ``(out, AttnStats | None)``. ``priority`` ranks auto-selection
    off-TPU, ``tpu_priority`` on TPU (Pallas backends invert the order).
    """

    name: str
    run: Callable
    supports: Callable[[AttnCall], bool]
    priority: int
    tpu_priority: int
    tags: frozenset

    def rank(self, call: AttnCall) -> int:
        del call  # ranking is platform-, not call-, dependent today
        return self.tpu_priority if _on_tpu() else self.priority


_REGISTRY: Dict[str, Backend] = {}
_LOADED = False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def register_backend(name: str, *, supports: Callable[[AttnCall], bool],
                     priority: int, tpu_priority: Optional[int] = None,
                     tags=()):
    """Decorator registering ``fn`` as backend ``name``."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(
            name=name, run=fn, supports=supports, priority=priority,
            tpu_priority=priority if tpu_priority is None else tpu_priority,
            tags=frozenset(tags))
        return fn

    return deco


def _ensure_backends() -> None:
    """Import the backend modules lazily (they import the model layer,
    which imports this package — top-level imports would cycle)."""
    global _LOADED
    if not _LOADED:
        _LOADED = True
        for mod in _BACKEND_MODULES:
            importlib.import_module(mod)


def list_backends() -> List[Backend]:
    _ensure_backends()
    return sorted(_REGISTRY.values(), key=lambda b: (-b.priority, b.name))


def get_backend(name: str) -> Backend:
    _ensure_backends()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def known_backend_names() -> List[str]:
    """Every resolvable request: backend names, family tags, "auto"."""
    _ensure_backends()
    names = {n for b in _REGISTRY.values() for n in (b.name, *b.tags)}
    return sorted(names | {"auto"})


def default_spec() -> AttnSpec:
    """The spec used when none is threaded (honors REPRO_ATTN_BACKEND)."""
    return AttnSpec(backend=os.environ.get(BACKEND_ENV, "auto"))


def effective_policy(spec: AttnSpec) -> str:
    """The selection policy ``spec`` actually runs under: its own unless
    "auto", in which case REPRO_ATTN_POLICY=cost opts the process in."""
    if spec.policy != "auto":
        return spec.policy
    return ("cost" if os.environ.get(POLICY_ENV, "").strip() == "cost"
            else "static")


_COST_WARNED = False


def resolve_backend(call: AttnCall, spec: Optional[AttnSpec] = None, *,
                    sig=None, tuner=None) -> Backend:
    """Pick the backend serving ``call`` under ``spec`` (static logic).

    ``sig`` (a :class:`repro.autotune.cost.CallSig`) activates cost-based
    ranking of the auto candidates when the spec's effective policy is
    "cost"; without it (or under explicit requests) the static priority
    order decides. ``tuner`` overrides the process-default tuner.
    """
    _ensure_backends()
    spec = spec if spec is not None else default_spec()
    cands = [b for b in _REGISTRY.values() if b.supports(call)]
    if not cands:
        raise BackendUnsupported(f"no registered backend supports {call}")

    def best(pool):
        return max(pool, key=lambda b: (b.rank(call), b.name))

    req = spec.requested_for(call.mode)
    if req == "auto":
        # "auto" always consults the env override, so REPRO_ATTN_BACKEND
        # forces the oracle end-to-end even through explicit specs that
        # only pin the layout; explicit non-auto requests still win
        req = os.environ.get(BACKEND_ENV, "auto")
    if req == "auto" and sig is not None and effective_policy(spec) == "cost":
        try:
            if tuner is None:
                from repro.autotune.tuner import default_tuner
                tuner = default_tuner()
            return tuner.choose(call, sig, cands)
        except Exception:
            # never let a cost-model bug change dispatch correctness —
            # degrade to the static order, warn once per process
            global _COST_WARNED
            if not _COST_WARNED:
                _COST_WARNED = True
                import warnings
                warnings.warn("cost-policy backend selection failed; "
                              "falling back to static priority order",
                              RuntimeWarning, stacklevel=2)
            return best(cands)
    if req != "auto":
        known = {n for b in _REGISTRY.values() for n in (b.name, *b.tags)}
        if req not in known:
            raise KeyError(
                f"unknown attention backend {req!r}; registered: "
                f"{sorted(known)}")
        exact = _REGISTRY.get(req)
        if exact is not None and exact in cands:
            return exact
        tagged = [b for b in cands if req in b.tags]
        if tagged:
            return best(tagged)
        if not spec.allow_fallback:
            raise BackendUnsupported(
                f"backend {req!r} does not support {call} "
                "(allow_fallback=False)")
    return best(cands)


def attention(q, k, v, call: AttnCall, *, spec: Optional[AttnSpec] = None,
              q_pos=None, k_pos=None, cache=None, page_table=None):
    """Single dispatch entry: resolve a backend and run the call.

    q [B,N,G,Sq,hd]; k/v [B,Sk,N,hd] (dense layout; None for paged calls,
    whose K/V live in ``cache`` pools indexed by ``page_table``).
    ``q_pos``/``k_pos`` are broadcastable position arrays (-1 = invalid);
    they default to ``arange`` when omitted. Returns
    ``(out [B,N,G,Sq,hd], AttnStats | None)``.
    """
    import jax.numpy as jnp

    if q_pos is None:
        q_pos = jnp.arange(q.shape[-2])
    if k_pos is None and k is not None:
        k_pos = jnp.arange(k.shape[1])
    sig = None
    eff_spec = spec if spec is not None else default_spec()
    if (effective_policy(eff_spec) == "cost"
            and eff_spec.requested_for(call.mode) == "auto"):
        # shapes/dtypes are static under tracing, so the signature (and
        # hence the choice) is burnt into the compiled program
        from repro.autotune.cost import call_signature
        from repro.distribution.tp import active_tp

        # under tensor-parallel serving this runs inside shard_map, so
        # q/cache shapes are already per-shard (local heads); the tp
        # degree keys the signature so cached probe results never cross
        # mesh shapes, and funds the collective term in the cost model
        sig = call_signature(call, q, k=k, cache=cache,
                             page_table=page_table, tp=active_tp())
    backend = resolve_backend(call, eff_spec, sig=sig)
    return backend.run(q, k, v, call, q_pos=q_pos, k_pos=k_pos,
                       cache=cache, page_table=page_table)
