"""The production backends, ported from the former ad-hoc entry points.

Seven implementations, one registry (reference lives in reference.py):

| backend            | ports                                        | calls it supports                    |
|--------------------|----------------------------------------------|--------------------------------------|
| xla_dense          | chunked/local/decode_attention               | HDP off (dense; paged decode)        |
| xla_hdp            | hdp_prefill/decode_attention                 | HDP on, dense layout (+draft/verify) |
| paged_hdp_decode   | hdp_paged_decode_attention (XLA stage 3)     | HDP on, paged decode (+draft/verify) |
| pallas_flash       | kernels.flash_attention                      | HDP off, aligned self-attn prefill   |
| pallas_hdp_block   | kernels.ops.hdp_attention_tpu / FUM stage 3  | HDP on, aligned prefill or paged     |
| pallas_paged_decode| kernels.hdp_paged_decode (gather-free FUM)   | HDP on, causal paged (+verify)       |

Pallas backends rank above XLA only on TPU (``pallas_paged_decode``
out-ranks ``pallas_hdp_block`` there: it streams surviving pages straight
from the pool instead of densifying first, so pruned pages cost no HBM
traffic at all); off-TPU they run in interpret mode when explicitly
requested and are never auto-selected. None has a VJP, so none supports
trainable calls, and the FUM kernels' per-row validity (cols < kv_len)
cannot express a sliding window's lower bound — windowed calls fall back
to the XLA chain.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.attention.reference import _densify
from repro.attention.registry import register_backend
from repro.attention.spec import AttnCall
from repro.attention.stats import normalize_stats
from repro.models import attention as A


def _heads(x, G):
    """[B,Sk,N,hd] -> [B,N*G,Sk,hd] (repeat KV heads across the group)."""
    return jnp.repeat(x.transpose(0, 2, 1, 3), G, axis=1)


# ------------------------------------------------------------------ xla_dense
def _supports_xla_dense(call: AttnCall) -> bool:
    return call.hdp is None


@register_backend("xla_dense", supports=_supports_xla_dense, priority=10,
                  tags=("xla",))
def run_xla_dense(q, k, v, call, *, q_pos, k_pos, cache=None, page_table=None):
    if call.layout == "paged":
        k, v, _ = _densify(cache, page_table)
    if call.mode == "decode":
        o = A.decode_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                               window=call.window, causal=call.causal)
    elif (call.window and q.shape[3] > call.window
          and k.shape[1] == q.shape[3]):
        # block-local path needs aligned q/k; chunked serving prefill
        # (q = one chunk, k = whole cache) windows via chunked_attention
        o = A.local_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                              window=call.window, causal=call.causal)
    else:
        chunk = call.chunk if call.chunk else k.shape[1]
        o = A.chunked_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                chunk=min(chunk, max(k.shape[1], 1)),
                                causal=call.causal, window=call.window)
    return o, None


# -------------------------------------------------------------------- xla_hdp
def _supports_xla_hdp(call: AttnCall) -> bool:
    return call.hdp is not None and call.layout == "dense"


@register_backend("xla_hdp", supports=_supports_xla_hdp, priority=10,
                  tags=("xla",))
def run_xla_hdp(q, k, v, call, *, q_pos, k_pos, cache=None, page_table=None):
    if call.mode == "decode":
        out, st = A.hdp_decode_attention(
            q, k, v, q_pos=q_pos, k_pos=k_pos, hdp=call.hdp,
            window=call.window, return_stats=call.needs_stats,
            draft=call.draft, per_query=call.verify)
    else:
        out, st = A.hdp_prefill_attention(
            q, k, v, q_pos=q_pos, k_pos=k_pos, hdp=call.hdp,
            window=call.window, return_stats=call.needs_stats)
    return out, normalize_stats(st)


# ----------------------------------------------------------- paged_hdp_decode
def _supports_paged_hdp(call: AttnCall) -> bool:
    return call.hdp is not None and call.layout == "paged"


def _run_paged(q, call, *, q_pos, k_pos, cache, page_table, stage3):
    # quantized pools carry no scout copies (k_scout is None; the scout
    # is a view of the int8 codes) and per-page scales instead
    out, st = A.hdp_paged_decode_attention(
        q, cache["k_pages"], cache["v_pages"], cache.get("k_scout"),
        page_table,
        q_pos=q_pos, k_pos=k_pos, hdp=call.hdp, window=call.window,
        return_stats=call.needs_stats, stage3=stage3,
        draft=call.draft, per_query=call.verify,
        fk_pool=cache.get("f_scout"),
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        kv_scale=getattr(call, "kv_scale", "grid"))
    return out, normalize_stats(st)


@register_backend("paged_hdp_decode", supports=_supports_paged_hdp,
                  priority=10, tags=("xla",))
def run_paged_hdp_decode(q, k, v, call, *, q_pos, k_pos, cache=None,
                         page_table=None):
    return _run_paged(q, call, q_pos=q_pos, k_pos=k_pos, cache=cache,
                      page_table=page_table, stage3="xla")


# --------------------------------------------------------------- pallas_flash
def _supports_pallas_flash(call: AttnCall) -> bool:
    return (call.hdp is None and call.layout == "dense"
            and call.mode == "prefill" and call.self_aligned
            and not call.per_slot and not call.trainable
            and call.window == 0)


@register_backend("pallas_flash", supports=_supports_pallas_flash,
                  priority=5, tpu_priority=20, tags=("pallas",))
def run_pallas_flash(q, k, v, call, *, q_pos, k_pos, cache=None,
                     page_table=None):
    from repro.kernels.ops import flash
    B, N, G, Sq, hd = q.shape
    out = flash(q.reshape(B, N * G, Sq, hd), _heads(k, G), _heads(v, G),
                causal=call.causal)
    return out.reshape(B, N, G, Sq, hd), None


# ----------------------------------------------------------- pallas_hdp_block
def _supports_pallas_hdp(call: AttnCall) -> bool:
    if call.hdp is None or call.trainable or call.window != 0 \
            or call.hdp.approx_softmax:
        return False
    if call.draft is not None or call.verify:
        # the block kernel computes neither the draft score sources nor
        # per-query-row scouts; speculative calls fall down the chain
        return False
    if call.layout == "paged":
        return True
    return (call.mode == "prefill" and call.self_aligned
            and not call.per_slot and call.hdp.causal == call.causal)


@register_backend("pallas_hdp_block", supports=_supports_pallas_hdp,
                  priority=5, tpu_priority=20, tags=("pallas",))
def run_pallas_hdp_block(q, k, v, call, *, q_pos, k_pos, cache=None,
                         page_table=None):
    if call.layout == "paged":
        return _run_paged(q, call, q_pos=q_pos, k_pos=k_pos, cache=cache,
                          page_table=page_table, stage3="pallas_block")
    from repro.kernels.ops import hdp_attention_tpu
    B, N, G, Sq, hd = q.shape
    out, st = hdp_attention_tpu(
        q.reshape(B, N * G, Sq, hd), _heads(k, G), _heads(v, G), call.hdp,
        return_stats=call.needs_stats)
    return out.reshape(B, N, G, Sq, hd), normalize_stats(st)


# --------------------------------------------------------- pallas_paged_decode
def _supports_pallas_paged(call: AttnCall) -> bool:
    """Gather-free FUM decode: page table drives the kernel's DMA directly.

    Needs the plain causal paged-decode shape: the kernel's per-row
    validity is ``cols < kv_len`` (upper bound only), which is exactly the
    causal mask of single-token decode — or of a multi-query verify call,
    whose consecutive rows each extend the bound by their query index —
    but cannot express a sliding window's lower bound or a non-causal
    extent. Draft calls fall down the chain: the kernel reads the
    full-precision pool, which the draft score sources never touch.
    """
    return (call.hdp is not None and call.layout == "paged"
            and call.mode == "decode" and not call.trainable
            and call.window == 0 and not call.hdp.approx_softmax
            and call.causal and call.hdp.causal and call.draft is None)


@register_backend("pallas_paged_decode", supports=_supports_pallas_paged,
                  priority=6, tpu_priority=25, tags=("pallas",))
def run_pallas_paged_decode(q, k, v, call, *, q_pos, k_pos, cache=None,
                            page_table=None):
    return _run_paged(q, call, q_pos=q_pos, k_pos=k_pos, cache=cache,
                      page_table=page_table, stage3="pallas_paged")
