"""RWKV6-3B "Finch" [arXiv:2404.05892; hf]: 32L d=2560 attention-free,
d_ff=8960, vocab 65536 — data-dependent decay.

HDP is INAPPLICABLE (no attention score matrix) — implemented without the
technique per DESIGN.md §Arch-applicability; hdp=None.
"""
from repro.configs.base import ModelConfig, register


@register
def rwkv6_3b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="rwkv6",
        n_layers=32,
        d_model=2560,
        n_heads=40,            # d / ssm_head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        ssm_head_dim=64,
        norm="layernorm",
        pos_emb="none",
        hdp=None,
        notes="attention-free: no QK^T exists, HDP inapplicable.",
    )
