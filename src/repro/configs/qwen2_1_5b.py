"""Qwen2-1.5B [arXiv:2407.10671; hf]: 28L d=1536 12H (kv=2) d_ff=8960,
vocab 151936 — GQA with QKV bias."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def qwen2_1_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151_936,
        act="silu_glu",
        qkv_bias=True,
        tie_embeddings=True,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="QKV biases are quantized with the activations before the "
              "integer scout (they shift the integer parts).",
    )
