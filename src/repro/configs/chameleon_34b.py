"""Chameleon-34B [arXiv:2405.09818; unverified]: 48L d=8192 64H (kv=8)
d_ff=22016, vocab 65536 — early-fusion VQ image tokens, qk-norm."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def chameleon_34b() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        act="silu_glu",
        qk_norm=True,  # chameleon's training-stability fix
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="VQ image tokens live in the vocab; frontend is the VQ "
              "tokenizer (stub — token ids arrive pre-quantized). qk-norm "
              "runs before HDP quantization.",
    )
