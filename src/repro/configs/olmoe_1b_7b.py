"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d=2048 16H (kv=16) d_ff=1024,
vocab 50304, MoE 64 experts top-8."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        n_experts=64,
        n_experts_active=8,
        act="silu_glu",
        qk_norm=True,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="MoE FFN untouched by HDP (attention-only technique).",
    )
