"""H2O-Danube-1.8B [arXiv:2401.16818; hf]: 24L d=2560 32H (kv=8)
d_ff=6912, vocab 32000 — llama+mistral mix with sliding-window attention."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def h2o_danube() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        act="silu_glu",
        sliding_window=4096,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="SWA makes this arch sub-quadratic: long_500k runs with a "
              "ring-buffered window cache; HDP mask composes with the band.",
    )
