"""Nemotron-4-15B [arXiv:2402.16819; unverified]: 32L d=6144 48H (kv=8)
d_ff=24576, vocab 256000 — GQA, squared-ReLU (non-gated) MLP."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def nemotron_4_15b() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=256_000,
        act="relu2",
        rope_theta=10_000.0,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
    )
