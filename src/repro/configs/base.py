"""Model / shape configuration system.

Every assigned architecture registers a :class:`ModelConfig` here via
:func:`register`; shapes are the four assigned input-shape sets. The
dry-run, smoke tests, benchmarks and launchers all select through
``get_config(name)`` / ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.config import HDPConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (see configs/<id>.py for the 10 assigned)."""

    name: str
    family: str                    # dense | moe | rwkv6 | zamba2 | whisper
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # transformer variants
    act: str = "silu_glu"          # silu_glu | gelu | relu2
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_emb: str = "rope"          # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    sliding_window: int = 0        # 0 = full attention
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group: int = 2048          # GShard group size (capacity per group)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128           # SSD chunked-dual-form chunk length
    attn_every: int = 0            # zamba2: shared attn block period

    # whisper / enc-dec
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_source_positions: int = 0  # encoder frame positions (stub frontend)

    # HDP (None -> plain attention; attention-free archs must use None)
    hdp: Optional[HDPConfig] = None

    # numerics / implementation
    dtype: str = "bfloat16"        # activation/param storage dtype
    attn_impl: str = "jnp"         # jnp (chunked, XLA) | pallas (TPU kernels)
    attn_chunk: int = 1024         # KV chunk for the chunked jnp path
    remat: bool = True

    # notes recorded in DESIGN.md (applicability etc.)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "whisper"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k (sub-quadratic sequence mixing)?"""
        return self.family in ("rwkv6", "zamba2") or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline bookkeeping)."""
        from repro.models import registry  # lazy; avoids cycle
        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    _ensure_imported()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> Tuple[str, ...]:
    _ensure_imported()
    return tuple(sorted(_REGISTRY))


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) runnable? Returns (ok, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: long_500k requires sub-quadratic "
                       "sequence mixing (DESIGN.md §Arch-applicability)")
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        remat=False,
        attn_chunk=32,
    )
    if cfg.n_experts:
        # capacity high enough that smoke tests never drop tokens (keeps
        # prefill+decode exactly equivalent to the full forward)
        kw.update(n_experts=4, n_experts_active=min(cfg.n_experts_active, 2),
                  capacity_factor=4.0)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.family in ("rwkv6", "zamba2"):
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        kw.update(attn_every=2, n_layers=5)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, decoder_layers=2, max_source_positions=64)
    if cfg.hdp is not None:
        kw.update(hdp=cfg.hdp.replace(block_q=2, block_k=2))
    return cfg.replace(**kw)


def _ensure_imported() -> None:
    # importing repro.configs pulls in every <id>.py (side-effect registry)
    import repro.configs  # noqa: F401
