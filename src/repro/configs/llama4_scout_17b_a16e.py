"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]:
48L d=5120 40H (kv=8) d_ff=8192, vocab 202048, MoE 16e top-1 + shared
expert, early fusion."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def llama4_scout() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=16,
        n_experts_active=1,
        n_shared_experts=1,
        act="silu_glu",
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="top-1 routing + always-on shared expert; early fusion means "
              "image tokens share the vocab (frontend out of scope).",
    )
