"""Whisper-large-v3 [arXiv:2212.04356; unverified]: 32L enc + 32L dec,
d=1280 20H (kv=20) d_ff=5120, vocab 51866 — enc-dec, conv frontend STUB
(input_specs provides precomputed frame embeddings)."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def whisper_large_v3() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="whisper",
        n_layers=32,
        encoder_layers=32,
        decoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        pos_emb="sinusoidal",
        tie_embeddings=True,
        max_source_positions=1500,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="frontend stub per assignment; decoder positions sinusoidal "
              "(learned 448-entry table too small for assigned 32k decode).",
    )
