"""Importing this package registers every assigned architecture."""
from repro.configs import (  # noqa: F401
    chameleon_34b,
    granite_8b,
    h2o_danube_1_8b,
    llama4_scout_17b_a16e,
    nemotron_4_15b,
    olmoe_1b_7b,
    qwen2_1_5b,
    rwkv6_3b,
    whisper_large_v3,
    zamba2_7b,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_applicable,
    get_config,
    list_configs,
    reduced,
)
