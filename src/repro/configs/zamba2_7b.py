"""Zamba2-7B [arXiv:2411.15242; unverified]: 81L d=3584 32H (kv=32)
d_ff=14336, vocab 32000, ssm_state=64 — Mamba2 backbone + shared attention
block (every 6 layers) with per-invocation LoRA.

HDP applies to the shared attention block only.
"""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="zamba2",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        attn_every=6,
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
        notes="Mamba2 blocks are attention-free (HDP n/a there); the shared "
              "attention block gets HDP.",
    )
