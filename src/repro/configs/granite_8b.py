"""Granite-8B (code) [arXiv:2405.04324; hf]: 36L d=4096 32H (kv=8)
d_ff=14336, vocab 49152 — llama-arch."""
from repro.configs.base import ModelConfig, register
from repro.core.config import HDPConfig


@register
def granite_8b() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        act="silu_glu",
        hdp=HDPConfig(block_q=128, block_k=128, rho_b=0.5, tau_h=0.0,
                      normalize_head_score=True, causal=True),
    )
