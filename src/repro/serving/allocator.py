"""Page ownership for the serving pool: refcounts + radix prefix cache.

`PageAllocator` extracts page *ownership* out of `PagedKVCache`: pages
are refcounted, so one physical page can back several logical owners —
a decode slot, another decode slot admitted with the same prompt prefix,
and the prefix cache itself — and is returned to the free list only when
the last owner lets go (a "true free"). The NaN-poison debugging contract
rides on that distinction: a freed-page poison hook fires on true free
only, never while any owner can still read the page.

`RadixPrefixCache` maps prompt prefixes to immutable full pages through a
token-chunk radix tree: each node holds exactly one page worth of prompt
tokens (the chunk tuple is the edge label — the "token hash" is Python's
tuple hashing in the children dict, with the stored tuple as the
collision-proof identity) plus the pool page id holding that chunk's
K/V. A resident node owns one allocator reference; a slot that matches a
path takes one more per page. Under pool pressure, least-recently-used
*leaf* nodes whose pages have no slot owners are evicted — interior
nodes are pinned by construction because a slot that references a child
page always references every ancestor page too.

Both classes are host-side bookkeeping over integer page ids; device
arrays (the pools, the tables) stay in `kv_cache.PagedKVCache`.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.common.transient import TransientError


class PoolExhausted(TransientError):
    """The page pool cannot satisfy an allocation right now.

    A *typed* exhaustion signal so callers can tell recoverable pressure
    (defer the request, evict, retry next tick — what the stream
    scheduler's token-budget admission does) from genuine bugs that also
    surface as RuntimeError (e.g. a stale donated-cache handle). It is a
    `TransientError`: retry layers (replica step retries, `fault.retry`)
    may back off and try again rather than failing over."""


class PageAllocator:
    """Refcounted free-list allocator over page ids ``[reserved, num_pages)``.

    Page ids below ``reserved`` (the scratch page) are never handed out.
    Freed pages return to the FRONT of the free list so the next
    allocation reuses the hottest pages — which also keeps reuse
    deterministic to test, matching the pre-refactor `PagedKVCache`
    behaviour.

    ``on_free(pages)`` is invoked with each batch of truly-freed page ids
    (refcount reached zero) — the pool wires its NaN-poison debug hook
    here, so poison can never land on a page that is still shared.
    """

    def __init__(self, num_pages: int, reserved: int = 1,
                 on_free: Optional[Callable[[List[int]], None]] = None):
        if num_pages <= reserved:
            raise ValueError(
                f"num_pages {num_pages} must exceed reserved {reserved}")
        self.num_pages = num_pages
        self.reserved = reserved
        self.on_free = on_free
        self._refs = [0] * num_pages
        self._free: List[int] = list(range(reserved, num_pages))
        self._in_use = 0

    # ------------------------------------------------------------- queries
    @property
    def capacity(self) -> int:
        return self.num_pages - self.reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Distinct pages with at least one owner (slot or cache)."""
        return self._in_use

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def assert_drained(self) -> None:
        """Raise AssertionError unless every page is back on the free list.

        The leak oracle for fault-path tests: after cancel/preempt/
        failover and a full drain, refcounts and pages-in-use must both
        be zero — any live page here is an unwind path that lost track
        of an owner.
        """
        leaked = [(p, self._refs[p]) for p in range(self.num_pages)
                  if self._refs[p] != 0]
        if leaked or self._in_use or len(self._free) != self.capacity:
            raise AssertionError(
                f"page pool not drained: in_use={self._in_use}, "
                f"free={len(self._free)}/{self.capacity}, "
                f"leaked refcounts={leaked[:16]}")

    # ----------------------------------------------------------- lifecycle
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list, each with refcount 1."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"page pool exhausted: need {n}, free {len(self._free)}")
        pages = self._free[:n]
        del self._free[:n]
        for p in pages:
            self._refs[p] = 1
        self._in_use += n
        return pages

    def ref(self, pages: Sequence[int]) -> None:
        """Add one owner to each page (pages must be live)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise ValueError(f"ref of free page {p}")
            self._refs[p] += 1

    def unref(self, pages: Sequence[int]) -> List[int]:
        """Drop one owner per page; returns the truly-freed subset.

        Truly-freed pages go to the FRONT of the free list and are
        reported to ``on_free`` — the only point where poison may land.
        """
        freed: List[int] = []
        for p in pages:
            r = self._refs[p]
            if r <= 0:
                raise ValueError(f"unref of free page {p} (double free?)")
            self._refs[p] = r - 1
            if r == 1:
                freed.append(p)
        if freed:
            self._free[:0] = freed
            self._in_use -= len(freed)
            if self.on_free is not None:
                self.on_free(list(freed))
        return freed


class _Node:
    __slots__ = ("chunk", "page", "children", "parent", "last_use")

    def __init__(self, chunk: Tuple[int, ...], page: int,
                 parent: "Optional[_Node]"):
        self.chunk = chunk
        self.page = page
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.last_use = 0


class RadixPrefixCache:
    """Token-chunk radix tree: prompt prefix -> immutable full pages.

    ``match`` walks the prompt in page-sized chunks and refs every page
    on the matched path *for the caller* (the admitting slot), so a
    matched page can never be evicted before the slot releases it.
    ``insert`` registers a freshly-prefilled prompt's full pages, taking
    one cache reference per newly-adopted page; chunks already resident
    keep their original page (the newcomer's duplicate stays slot-owned
    and simply is not cached). ``evict`` frees least-recently-used
    unpinned leaves until enough pages came back.
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        if page_size <= 0:
            raise ValueError(f"page_size {page_size}")
        self.alloc = alloc
        self.page_size = page_size
        self._root = _Node((), -1, None)
        self._clock = 0
        self._nodes = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0

    # ------------------------------------------------------------- queries
    @property
    def cached_pages(self) -> int:
        return self._nodes

    def _chunks(self, tokens: Sequence[int]):
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(tokens[i * ps:(i + 1) * ps])

    def peek(self, tokens: Sequence[int], align: int = 1) -> int:
        """Pages on the longest cached prefix of ``tokens`` — a read-only
        probe: no references taken, no hit/miss counters bumped, no LRU
        clocks touched. The stream scheduler's admission-ordering and
        token-budget signal (``match`` at admission time remains the one
        source of truth; a page evicted between peek and match just turns
        the hit into a smaller hit or a cold admission)."""
        node, n = self._root, 0
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            n += 1
            node = child
        return n - n % max(align, 1)

    def evictable_pages(self) -> int:
        """Pages ``evict`` could free right now if pressed hard enough.

        A node is reclaimable iff nothing in its subtree is pinned by a
        slot (refcount > 1): eviction peels leaves, so a pinned node
        blocks every ancestor, while sibling branches stay evictable.
        Used by the scheduler's token-budget admission: admission
        capacity = free pages + this."""
        def walk(n: _Node) -> Tuple[int, bool]:
            cnt, blocked = 0, False
            for c in n.children.values():
                c_cnt, c_blk = walk(c)
                cnt += c_cnt
                blocked |= c_blk
            if blocked or self.alloc.refcount(n.page) > 1:
                return cnt, True
            return cnt + 1, False

        return sum(walk(c)[0] for c in self._root.children.values())

    # --------------------------------------------------------------- match
    def match(self, tokens: Sequence[int], align: int = 1) -> List[int]:
        """Longest cached prefix of ``tokens`` as a list of page ids.

        ``align``: the match is trimmed to a multiple of this many pages
        (HDP q-block alignment) *before* refs are taken and counters
        bumped — a match trimmed to nothing is an honest miss. Every
        returned page carries one fresh reference owned by the caller
        (release with ``alloc.unref`` when the slot retires). Bumps LRU
        clocks along the walked path.
        """
        self._clock += 1
        node, pages = self._root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_use = self._clock
            pages.append(child.page)
            node = child
        pages = pages[:len(pages) - len(pages) % max(align, 1)]
        if pages:
            self.alloc.ref(pages)
            self.hits += 1
            self.hit_tokens += len(pages) * self.page_size
        else:
            self.misses += 1
        return pages

    # -------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Register ``pages`` as the full-page chain spelling ``tokens``.

        ``pages[i]`` must hold the K/V of tokens ``[i*ps, (i+1)*ps)`` and
        must never be written again by its owner (the engine guarantees
        this by only registering pages strictly before the decode write
        frontier — with speculative decode, strictly before the *commit*
        frontier, so staged/rolled-back positions can never be cached).
        Returns the number of newly-cached pages.
        """
        self._clock += 1
        node, added = self._root, 0
        for i, chunk in enumerate(self._chunks(tokens)):
            if i >= len(pages):
                break
            if pages[i] < self.alloc.reserved:
                # a reserved (scratch) id here means the caller handed a
                # write-redirected page to the cache — sharing it would
                # serve arbitrary staging garbage as prompt K/V
                raise ValueError(
                    f"cannot register reserved page {pages[i]} as a "
                    "prompt prefix")
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, pages[i], node)
                self.alloc.ref([pages[i]])
                node.children[chunk] = child
                self._nodes += 1
                added += 1
            child.last_use = self._clock
            node = child
        return added

    # --------------------------------------------------------------- evict
    def _evictable_leaves(self) -> List[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif self.alloc.refcount(n.page) == 1:  # cache is the only owner
                out.append(n)
        return out

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pages, LRU leaves first.

        A leaf whose page is still slot-referenced (refcount > 1) is
        pinned; evicting a leaf may expose its parent as the next LRU
        candidate, so the scan repeats until satisfied or dry.
        """
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            leaves.sort(key=lambda n: n.last_use)
            for leaf in leaves:
                leaf.parent.children.pop(leaf.chunk)
                self.alloc.unref([leaf.page])
                self._nodes -= 1
                self.evictions += 1
                freed += 1
                if freed >= n_pages:
                    break
        return freed

    def clear(self) -> int:
        """Drop every cached prefix (frees all cache-only pages)."""
        n = self._nodes
        while self._nodes:
            if not self.evict(self._nodes):
                break
        return n - self._nodes
