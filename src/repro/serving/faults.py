"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a schedule of failures pinned to engine step
numbers, parsed from a compact spec string (CLI ``--fault-plan`` or the
``REPRO_FAULT_PLAN`` env var)::

    kind@step[:key=value[,key=value...]][;kind@step...]

Kinds:

``exhaust@S``
    The next page reservation at or after step ``S`` raises
    :class:`~repro.serving.allocator.PoolExhausted` (the stream
    scheduler defers and retries; static admission propagates it).
``error@S``
    Step ``S`` raises :class:`InjectedFault` from inside the decode
    hot path, *after* the cache handle was taken for donation — the
    exact spot where ``restore_if_undonated`` must keep the engine
    usable.
``nan@S:uid=U``
    Request ``U``'s logits are forced to NaN at the first decode/verify
    step at or after ``S`` where it is active, tripping the per-slot
    tripwire (that request errors; batchmates must be unaffected).
``slow@S:s=0.05``
    Sleep ``s`` seconds at the top of step ``S`` (straggler).
``kill@S:replica=R``
    :class:`~repro.serving.replica.ReplicaSet` marks replica ``R`` dead
    before stepping at fleet step ``S`` and fails its work over.

Every event fires **once**, at the first opportunity at-or-after its
scheduled step, and is recorded in :attr:`FaultInjector.fired` — the
plan is a consumable schedule, not a rate. Engines sharing one
injector (``ReplicaSet.build``) therefore see each event exactly once
fleet-wide; engines constructed with separate injectors each consume
their own copy of the plan.

:class:`InjectedFault` is deliberately **not** a
:class:`~repro.common.transient.TransientError`: injected faults model
hard failures, so retry layers must not paper over them.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Set, Union

FAULT_ENV = "REPRO_FAULT_PLAN"

_KINDS = ("exhaust", "error", "nan", "slow", "kill")


class InjectedFault(RuntimeError):
    """A failure raised on purpose by the fault-injection harness."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: ``kind`` at engine/fleet step ``step``."""

    kind: str
    step: int
    uid: Optional[int] = None       # nan: target request uid
    replica: Optional[int] = None   # kill: target replica index
    seconds: float = 0.0            # slow: sleep duration

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {_KINDS})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind == "nan" and self.uid is None:
            raise ValueError("nan fault needs :uid=<request uid>")
        if self.kind == "kill" and self.replica is None:
            raise ValueError("kill fault needs :replica=<index>")
        if self.kind == "slow" and self.seconds <= 0:
            raise ValueError("slow fault needs :s=<seconds> > 0")

    @property
    def spec(self) -> str:
        parts = []
        if self.uid is not None:
            parts.append(f"uid={self.uid}")
        if self.replica is not None:
            parts.append(f"replica={self.replica}")
        if self.seconds:
            parts.append(f"s={self.seconds:g}")
        tail = f":{','.join(parts)}" if parts else ""
        return f"{self.kind}@{self.step}{tail}"


def _parse_event(item: str) -> FaultEvent:
    head, _, tail = item.partition(":")
    kind, at, step = head.partition("@")
    if not at or not step:
        raise ValueError(f"fault item {item!r} is not 'kind@step[:k=v,..]'")
    kw: Dict[str, Union[int, float]] = {}
    for pair in filter(None, tail.split(",")):
        key, eq, val = pair.partition("=")
        if not eq:
            raise ValueError(f"fault option {pair!r} is not 'key=value'")
        if key == "uid":
            kw["uid"] = int(val)
        elif key == "replica":
            kw["replica"] = int(val)
        elif key == "s":
            kw["seconds"] = float(val)
        else:
            raise ValueError(f"unknown fault option {key!r} in {item!r}")
    return FaultEvent(kind=kind.strip(), step=int(step), **kw)


class FaultPlan:
    """An ordered, immutable schedule of :class:`FaultEvent`s."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(events,
                                               key=lambda e: (e.step, e.kind))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        items = [s.strip() for s in spec.split(";") if s.strip()]
        return cls(_parse_event(s) for s in items)

    @property
    def spec(self) -> str:
        return ";".join(e.spec for e in self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FaultPlan({self.spec!r})"


class FaultInjector:
    """Consumes a :class:`FaultPlan` against a live engine/fleet.

    Each hook is called from a fixed spot in the serving loop with the
    current step number; pending events whose step has arrived fire
    (once) and move to :attr:`fired`.
    """

    def __init__(self, plan: Union[FaultPlan, str, None] = None):
        if isinstance(plan, str):
            plan = FaultPlan.parse(plan)
        self.plan = plan or FaultPlan()
        self._pending: List[FaultEvent] = list(self.plan.events)
        self.fired: List[FaultEvent] = []

    def _take(self, kind: str, step: int, pred=None) -> List[FaultEvent]:
        hit = [e for e in self._pending
               if e.kind == kind and e.step <= step
               and (pred is None or pred(e))]
        for e in hit:
            self._pending.remove(e)
            self.fired.append(e)
        return hit

    # ------------------------------------------------------------ hooks
    def sleep(self, step: int) -> None:
        """Top of ``Engine.step``: straggler injection."""
        for e in self._take("slow", step):
            time.sleep(e.seconds)

    def step_error(self, step: int) -> None:
        """Inside the donating decode call bracket: hard step failure."""
        hit = self._take("error", step)
        if hit:
            raise InjectedFault(
                f"injected step failure (scheduled step {hit[0].step})")

    def pool_exhausted(self, step: int) -> bool:
        """``Engine._reserve``: force one PoolExhausted admission failure."""
        return bool(self._take("exhaust", step))

    def nan_uids(self, step: int, live_uids: Set[int]) -> List[int]:
        """Uids whose logits this step must poison (only fires for
        requests that are actually active, so the tripwire is hit)."""
        hit = self._take("nan", step, pred=lambda e: e.uid in live_uids)
        return [e.uid for e in hit]

    def kills(self, step: int) -> List[int]:
        """``ReplicaSet.step``: replica indices to kill this step."""
        return [e.replica for e in self._take("kill", step)]

    # ------------------------------------------------------------ intro
    @property
    def pending(self) -> Sequence[FaultEvent]:
        return tuple(self._pending)

    def summary(self) -> dict:
        return {
            "plan": self.plan.spec,
            "fired": [e.spec for e in self.fired],
            "pending": [e.spec for e in self._pending],
        }


def coerce_injector(
    faults: Union[FaultInjector, FaultPlan, str, None],
    *,
    env: bool = True,
) -> Optional[FaultInjector]:
    """Normalize a ``faults=`` argument to a shared injector (or None).

    ``None`` falls back to ``REPRO_FAULT_PLAN`` when ``env`` is set — the
    zero-code path to chaos-test any serving entry point.
    """
    if isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, (FaultPlan, str)):
        return FaultInjector(faults) if faults else None
    if faults is None and env:
        spec = os.environ.get(FAULT_ENV, "").strip()
        if spec:
            return FaultInjector(spec)
    return None
