"""Continuous-batching stream scheduler over the engine's slot/page machinery.

`StreamScheduler` turns the engine's fixed-wave admission into an
SGLang-style streaming serve loop. It owns the waiting queue and runs
once per engine step (``tick``), between decode horizons / speculative
rounds, doing three things:

* **Token-budget admission.** A waiting request is admitted only when a
  decode slot is free AND the page pool can hold its whole footprint
  (prompt + output budget, via ``Engine._pages_for``), counting pages an
  LRU eviction could reclaim (``RadixPrefixCache.evictable_pages``) as
  capacity. When the head of the queue does not fit, admission stops —
  head-of-line blocking is deliberate: skipping ahead to smaller
  requests forever would starve big ones. Because finished slots free
  their pages mid-run (``Engine._finish``), a queued request prefills
  into the vacated slot at the very next tick — in-flight slot
  recycling, no drain barrier between "waves".

* **Prefix-cache-aware ordering.** With the radix tree enabled, waiting
  requests are ordered biggest-cached-prefix-first each tick
  (``RadixPrefixCache.peek`` — a ref-free probe, so hit/miss counters
  stay honest), FIFO within ties. A hit both prefills less and needs
  fewer fresh pages, so serving it first maximizes throughput under
  pool pressure; the budget check uses the peeked hit to charge only
  the fresh (unshared) pages.

* **Chunked prefill interleaved with decode.** A long cold prompt
  (longer than the largest prefill bucket) is NOT prefilled in one
  blocking loop: the scheduler opens an incremental prefill
  (``Engine._begin_stream_prefill`` reserves the slot + pages up front,
  so completion is guaranteed) and advances it by at most
  ``prefill_chunk_tokens`` per tick, so the running batch keeps
  decoding between chunks and admission of shorter requests continues
  around it. One interleaved prefill runs at a time; the per-request
  tokens are identical to a one-shot prefill (the chunked-prefill
  equivalence pinned in tests/test_paged_cache.py), so interleaving is
  invisible to outputs.

A **watchdog** closes the loop: if the engine makes no progress — no
tokens decoded, nothing admitted, no prefill chunk advanced — for
``watchdog_steps`` consecutive steps (or ``watchdog_s`` wall seconds)
while requests are still waiting, the stalled queue head is *shed* as a
per-request ``Result(status="error")`` and serving continues (the
classic case: a request whose page footprint exceeds what the pool can
ever offer should fail alone, not kill the loop). After
``watchdog_escalation`` sheds the next trip escalates to the legacy
loop-fatal `WatchdogError` — repeated stalls mean the engine itself is
wedged, not one bad request.

**Preempt-and-restore** handles the opposite starvation: when the queue
head has waited ``preempt_after`` consecutive no-admission ticks, the
scheduler may preempt a strictly-lower-priority *running* request
(vLLM-style recompute: free its slot and non-shared pages, requeue it
with its generated tokens folded into the prompt) so the head admits
instead of head-of-line blocking forever. Greedy decode plus the
chunked-prefill equivalence make the victim's eventual resume
byte-identical — and cheap when the prefix cache still holds its pages.

The scheduler is pure host-side policy: every device-touching action
(prefill jits, page reservation, slot install) goes through the engine's
existing admission paths, so batched bucketed prefill, prefix-hit
serving, COW and all unwind/requeue invariants are reused, not
reimplemented.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional

from repro.common.transient import TransientError
from repro.serving.allocator import PoolExhausted

if TYPE_CHECKING:  # import cycle: engine constructs the scheduler
    from repro.serving.engine import Engine, Request


class WatchdogError(RuntimeError):
    """The streaming serve loop stalled with requests still pending."""


class QueueFull(TransientError):
    """``submit()`` rejected: the waiting queue is at ``max_queue_depth``.

    Typed backpressure instead of unbounded queue growth; it is a
    `TransientError` — clients should back off and resubmit."""


@dataclasses.dataclass
class SchedulerConfig:
    """Knobs for `StreamScheduler` (see the module docstring).

    prefill_chunk_tokens: interleaved-prefill token budget per engine
        step; None = one largest-bucket chunk per step. At least one
        chunk always runs per tick, so progress is guaranteed even when
        the budget is smaller than a chunk.
    order: "prefix" admits highest `Request.priority` first, then
        biggest peeked cache hit (FIFO among ties and whenever the
        prefix cache is off); "fifo" disables the reordering entirely.
    watchdog_steps / watchdog_s: consecutive no-progress engine steps /
        wall seconds with pending requests before the watchdog trips.
    watchdog_escalation: a watchdog trip sheds the stalled queue head as
        a per-request ``Result(status="error")`` and keeps serving; after
        this many sheds the next trip raises `WatchdogError` (0 = legacy
        loop-fatal on the first trip).
    max_queue_depth: bound on ``depth``; ``submit()`` past it raises
        `QueueFull`. None = unbounded (legacy).
    preempt_after: consecutive no-admission ticks with work waiting
        before a strictly-lower-priority running request may be
        preempted (recompute-requeued) to unblock the queue head.
        None disables preemption.
    """

    prefill_chunk_tokens: Optional[int] = None
    order: str = "prefix"
    watchdog_steps: int = 500
    watchdog_s: float = 120.0
    watchdog_escalation: int = 8
    max_queue_depth: Optional[int] = None
    preempt_after: Optional[int] = 4

    def __post_init__(self):
        if self.order not in ("prefix", "fifo"):
            raise ValueError(f"order must be 'prefix' or 'fifo', "
                             f"got {self.order!r}")
        if self.watchdog_steps < 1:
            raise ValueError(
                f"watchdog_steps must be >= 1, got {self.watchdog_steps}")
        if self.prefill_chunk_tokens is not None \
                and self.prefill_chunk_tokens < 1:
            raise ValueError(f"prefill_chunk_tokens must be >= 1, got "
                             f"{self.prefill_chunk_tokens}")
        if self.watchdog_escalation < 0:
            raise ValueError(f"watchdog_escalation must be >= 0, got "
                             f"{self.watchdog_escalation}")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, got "
                             f"{self.max_queue_depth}")
        if self.preempt_after is not None and self.preempt_after < 1:
            raise ValueError(f"preempt_after must be >= 1, got "
                             f"{self.preempt_after}")


@dataclasses.dataclass
class _Waiting:
    seq: int          # submission order — the FIFO tiebreak
    req: "Request"


class StreamScheduler:
    """Host-side admission policy driven by ``Engine.step`` (one tick
    per step). See the module docstring for the full contract."""

    def __init__(self, engine: "Engine", cfg: SchedulerConfig):
        self.eng = engine
        self.cfg = cfg
        self.waiting: List[_Waiting] = []
        self._seq = 0
        #: in-flight interleaved chunked prefill (Engine._begin_stream_prefill
        #: state dict), at most one at a time
        self._chunk: Optional[Dict[str, Any]] = None
        self._idle_steps = 0
        self._last_progress = time.perf_counter()
        #: watchdog trips so far (each shed one stalled request)
        self._trips = 0
        #: consecutive ticks the waiting head failed to admit — the
        #: preempt-and-restore trigger
        self._hol_ticks = 0
        #: admission log (uids in service-entry order) — tests pin the
        #: prefix-hit-first ordering through it
        self.admitted_uids: List[int] = []

    # -------------------------------------------------------------- queries
    @property
    def depth(self) -> int:
        """Requests not yet decoding: waiting + mid-interleaved-prefill."""
        return len(self.waiting) + (1 if self._chunk is not None else 0)

    @property
    def prefilling(self) -> bool:
        return self._chunk is not None

    def pending_requests(self) -> List["Request"]:
        reqs = [w.req for w in self.waiting]
        if self._chunk is not None:
            reqs.insert(0, self._chunk["req"])
        return reqs

    # ------------------------------------------------------------- enqueue
    def enqueue(self, req: "Request") -> None:
        self.waiting.append(_Waiting(self._seq, req))
        self._seq += 1

    # ---------------------------------------------------------------- tick
    def tick(self) -> bool:
        """One scheduling pass (runs before the step's decode): advance
        the in-flight chunked prefill, then admit what fits. Returns
        whether anything progressed (the watchdog's signal when no slot
        is decoding)."""
        progressed = self._advance_chunk()
        progressed |= self._admit()
        return progressed

    def watchdog(self, progressed: bool) -> None:
        """Called once per engine step with that step's overall progress
        (any decode token, admission, or prefill chunk). A trip — after
        ``watchdog_steps`` consecutive idle steps or ``watchdog_s`` idle
        wall seconds with requests pending — sheds the stalled queue
        head as a per-request failure and keeps serving; past
        ``watchdog_escalation`` sheds (or with escalation 0) it raises
        `WatchdogError` instead."""
        now = time.perf_counter()
        if progressed or self.depth == 0:
            self._idle_steps = 0
            self._last_progress = now
            return
        self._idle_steps += 1
        if self._idle_steps < self.cfg.watchdog_steps \
                and now - self._last_progress < self.cfg.watchdog_s:
            return
        uids = [r.uid for r in self.pending_requests()]
        msg = (f"stream scheduler stalled: no decode, admission or "
               f"prefill progress for {self._idle_steps} engine steps "
               f"({now - self._last_progress:.1f}s) with request(s) "
               f"{uids} pending — the queue head's slot/page footprint "
               f"can never be satisfied, or the engine is wedged")
        self._trips += 1
        esc = self.cfg.watchdog_escalation
        if esc == 0 or self._trips > esc or not self._shed_stalled(msg):
            raise WatchdogError(msg)
        self._idle_steps = 0
        self._last_progress = now

    def _shed_stalled(self, msg: str) -> bool:
        """Fail the stalled queue head (admission order) as a typed
        per-request error so the loop survives one bad request."""
        eng = self.eng
        if self.waiting:
            scored = [(w, self._hit_pages(w.req)) for w in self.waiting]
            if self.cfg.order == "prefix":
                scored.sort(key=lambda p: (-p[0].req.priority, -p[1],
                                           p[0].seq))
            w = scored[0][0]
            self.waiting.remove(w)
            victim = w.req
        elif self._chunk is not None:
            st = self._chunk
            self._chunk = None
            eng._abort_stream_prefill(st)
            victim = st["req"]
        else:
            return False
        eng.metrics["watchdog_shed"] += 1
        eng._fail_request(victim, status="error", error=f"watchdog: {msg}")
        return True

    # ------------------------------------------------------------- cancel
    def cancel(self, uid: int) -> Optional["Request"]:
        """Remove ``uid`` from the waiting queue or the in-flight chunked
        prefill (unwinding its slot/page reservation); returns the
        request so the engine can finish it with a typed Result, or
        None when ``uid`` is not queued here."""
        for w in self.waiting:
            if w.req.uid == uid:
                self.waiting.remove(w)
                return w.req
        if self._chunk is not None and self._chunk["req"].uid == uid:
            st = self._chunk
            self._chunk = None
            self.eng._abort_stream_prefill(st)
            return st["req"]
        return None

    # ----------------------------------------------------------- admission
    def _hit_pages(self, req: "Request") -> int:
        eng = self.eng
        if eng.prefix is None:
            return 0
        return eng.prefix.peek(req.prompt, align=eng._page_align)

    def _fresh_pages_for(self, req: "Request", hit: int) -> int:
        """Fresh pool pages an admission would need (shared hit pages are
        free; a full-prompt hit still COWs one page — mirrors
        Engine._serve_hit's reservation arithmetic)."""
        eng = self.eng
        if not eng.paged:
            return 0
        need = eng._pages_for(req)
        if hit:
            full = hit * eng.pages.page_size == len(req.prompt)
            need = need - hit + (1 if full else 0)
        return need

    def _is_long_cold(self, req: "Request", hit: int) -> bool:
        eng = self.eng
        return (hit == 0 and eng._can_chunk
                and len(req.prompt) > eng.buckets[-1])

    def _admit(self) -> bool:
        """Admit the largest prefix of the (ordered) waiting queue that
        fits the slot + page budget; long cold prompts open the
        interleaved prefill instead of a blocking one. Tracks head-of-
        line starvation and preempts lower-priority runners past the
        ``preempt_after`` threshold."""
        eng = self.eng
        if not self.waiting:
            self._hol_ticks = 0
            return False
        scored = [(w, self._hit_pages(w.req)) for w in self.waiting]
        if self.cfg.order == "prefix":
            scored.sort(key=lambda p: (-p[0].req.priority, -p[1], p[0].seq))
        if self.cfg.preempt_after is not None \
                and self._hol_ticks >= self.cfg.preempt_after:
            self._preempt_for(scored[0][0].req, scored[0][1])
        if not eng._free:
            self._hol_ticks += 1
            return False
        free = len(eng._free)
        cap = eng._pages_capacity() if eng.paged else None
        stage: List[_Waiting] = []
        progressed = False
        for w, hit in scored:
            if free == 0:
                break
            need = self._fresh_pages_for(w.req, hit)
            if cap is not None and need > cap:
                # token budget: the head blocks (skipping ahead forever
                # would starve it); retried next tick once slots finish
                eng.metrics["sched_deferred"] += 1
                break
            if self._is_long_cold(w.req, hit):
                if self._chunk is not None:
                    # one interleaved prefill at a time — shorter
                    # requests behind it keep flowing
                    continue
                # begin before dequeue: a reservation failure leaves the
                # request waiting instead of dropping it
                self._chunk = eng._begin_stream_prefill(w.req)
                self.waiting.remove(w)
                self._note_admitted(w.req.uid)
                progressed = True
            else:
                stage.append(w)
            free -= 1
            if cap is not None:
                cap -= need
        if stage:
            staged = {w.req.uid: w for w in stage}
            for w in stage:
                self.waiting.remove(w)
            eng._queue.extend(w.req for w in stage)
            try:
                eng._admit()
            except PoolExhausted:
                # the capacity estimate raced an eviction — the engine's
                # unwind already requeued the unadmitted requests, which
                # _reclaim below hands back to us for the next tick
                eng.metrics["sched_deferred"] += 1
            finally:
                returned = self._reclaim(staged)
            for w in stage:
                if w.req.uid not in returned:
                    self._note_admitted(w.req.uid)
                    progressed = True
        if progressed:
            self._hol_ticks = 0
        else:
            self._hol_ticks += 1
        return progressed

    # ---------------------------------------------- preempt-and-restore
    def _preempt_for(self, head: "Request", hit: int) -> bool:
        """Preempt strictly-lower-priority running requests until
        ``head`` fits (vLLM-style recompute): each victim frees its slot
        and non-shared pages and requeues with its generated tokens
        folded into the prompt, so its eventual resume — a plain
        re-admission through prefill — is byte-identical, and cheap
        while the prefix cache still holds the victim's pages."""
        eng = self.eng
        preempted = False
        while True:
            need = self._fresh_pages_for(head, hit)
            cap = eng._pages_capacity() if eng.paged else None
            if eng._free and (cap is None or need <= cap):
                break
            slot = eng._preempt_victim(head.priority)
            if slot is None:
                break
            self.enqueue(eng._preempt(slot))
            preempted = True
        if preempted:
            self._hol_ticks = 0
        return preempted

    def _reclaim(self, staged: Dict[int, _Waiting]) -> set:
        """Move whatever the engine unwound back to the waiting head,
        preserving original submission order; returns the unwound uids."""
        if not self.eng._queue:
            return set()
        back = []
        for req in self.eng._queue:
            w = staged.get(req.uid)
            back.append(w if w is not None else _Waiting(self._seq, req))
        self.eng._queue.clear()
        self.waiting[:0] = back
        return {w.req.uid for w in back}

    def _note_admitted(self, uid: int) -> None:
        self.admitted_uids.append(uid)
        m = self.eng.metrics
        m["sched_admitted"] += 1
        if m["decode_steps"] > 0:
            # decode already ran: this admission filled a slot vacated
            # mid-run — the continuous-batching recycle the bench pins
            m["sched_recycled"] += 1
            # a recycled slot changes the shape mix the engine serves;
            # give pending cost-policy probes a chance to settle before
            # the refilled batch decodes (no-op under static policy)
            self.eng._maybe_retune()

    # ---------------------------------------------- interleaved prefill
    def _advance_chunk(self) -> bool:
        """Run up to ``prefill_chunk_tokens`` of the in-flight prefill
        (at least one chunk), installing + activating it when done."""
        if self._chunk is None:
            return False
        eng = self.eng
        budget = self.cfg.prefill_chunk_tokens or eng.buckets[-1]
        st = self._chunk
        if eng._active:
            # a prefill slice about to run under a live decode batch —
            # the interleaving the chunked-prefill satellite tests pin
            eng.metrics["sched_interleaved_steps"] += 1
        try:
            done = eng._advance_stream_prefill(st, budget)
        except BaseException:
            self._chunk = None
            eng._abort_stream_prefill(st)
            if not st.get("installed"):
                self.waiting.insert(0, _Waiting(self._seq, st["req"]))
            raise
        if done:
            self._chunk = None
        return True
