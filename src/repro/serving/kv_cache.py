"""KV-cache management for batched serving: dense slots and block pages.

Two layouts, selected by the engine's ``cache_backend``:

* `SlotCache` — the dense per-slot contiguous layout. The decode cache
  for every family is a pytree whose leaves carry a ``batch`` axis (its
  index per leaf comes from ``registry.cache_specs``); ``insert`` copies
  one row of a freshly-prefilled request cache (possibly shorter
  ``max_len`` — bucketed/batched prefill) into a slot, ``clear`` zeroes a
  slot on completion. Works for every family, including recurrent state.
* `PagedKVCache` — the block-paged transformer layout: one shared page
  pool + per-slot page tables, page size = HDP's ``block_k`` so cache
  pages coincide with the integer scout's pruning blocks. The decode
  path gathers only scout-surviving pages (`hdp_paged_decode_attention`)
  — pruned pages are never read, which is the FUM memory-traffic win —
  and pages are allocated per request, which is the resident-bytes win.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


class DonatedCacheError(RuntimeError):
    """The live cache handle was donated to a jitted step and not replaced.

    Raised instead of letting XLA hit a deleted buffer: with
    ``donate_argnums`` the decode step aliases the page pool in place, so
    the pre-call handle is dead the moment the call is dispatched.
    Callers must bracket donating calls with ``take()`` / ``put()``.
    """


class _DonatableCache:
    """Mixin guarding the ``cache`` attribute across buffer donation."""

    _cache: Any = None

    @property
    def cache(self):
        if self._cache is None:
            raise DonatedCacheError(
                "KV cache handle was donated to a jitted decode step and "
                "not yet replaced; bracket donating calls with take()/put()")
        return self._cache

    @cache.setter
    def cache(self, value) -> None:
        self._cache = value

    def take(self):
        """Hand the live cache out for a donating call; the stored handle
        becomes invalid until ``put`` installs the aliased output."""
        c = self.cache
        self._cache = None
        return c

    def put(self, new_cache) -> None:
        if self._cache is not None:
            raise DonatedCacheError("put() without a prior take()")
        self._cache = new_cache


def _batch_axes(cfg) -> Any:
    """Cache-structured tree of the batch-axis index per leaf."""
    specs = registry.cache_specs(cfg)

    def one(ax):
        ax = tuple(ax)
        return ax.index("batch") if "batch" in ax else None

    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


class SlotCache(_DonatableCache):
    """Slot arithmetic over a family-agnostic cache pytree."""

    def __init__(self, cfg, batch: int, max_len: int, **cache_kw):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.cache = registry.init_cache(cfg, batch, max_len=max_len,
                                         **cache_kw)
        self.axes = _batch_axes(cfg)

    # ------------------------------------------------------------- insert
    def insert(self, one_cache, slot, row: int = 0) -> None:
        """Copy row `row` of a request cache into `slot` (in place on host)."""
        self.cache = insert_slot(self.cache, one_cache, slot, self.axes,
                                 row=row)

    def clear(self, slot) -> None:
        self.cache = clear_slot(self.cache, slot, self.axes)


def _dus_axis(big, small, slot, axis: int, row: int = 0):
    """dynamic_update_slice of row `row` of `small` into `big` at index
    `slot` of `axis`, zero-padding the sequence dims when the prefill cache
    is shorter (bucketed/batched prefill)."""
    if small.shape[axis] != 1:
        small = jax.lax.dynamic_slice_in_dim(small, row, 1, axis)
    # pad every non-batch dim that is shorter (bucketed prefill caches)
    pads = []
    for d, (bs, ss) in enumerate(zip(big.shape, small.shape)):
        if d == axis:
            pads.append((0, 0))
        else:
            if ss > bs:
                raise ValueError(
                    f"request cache dim {d} ({ss}) exceeds serving cache "
                    f"({bs})")
            pads.append((0, bs - ss))
    small = jnp.pad(small, pads)
    start = [jnp.asarray(0, jnp.int32)] * big.ndim
    start[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)


def insert_slot(batch_cache, one_cache, slot, axes, row: int = 0) -> Any:
    def one(big, small, ax):
        if ax is None:  # no batch axis (shared leaf) — keep serving copy
            return big
        return _dus_axis(big, small, slot, ax, row=row)

    return jax.tree.map(one, batch_cache, one_cache, axes)


def clear_slot(batch_cache, slot, axes) -> Any:
    def one(big, ax):
        if ax is None:
            return big
        shape = list(big.shape)
        shape[ax] = 1
        return _dus_axis(big, jnp.zeros(shape, big.dtype), slot, ax)

    return jax.tree.map(one, batch_cache, axes)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


# --------------------------------------------------------------------------
# Block-paged KV cache (transformer families)
# --------------------------------------------------------------------------
class PagedKVCache(_DonatableCache):
    """Page pool + per-slot page tables, aligned to HDP's ``block_k``.

    Layout: ``k_pages``/``v_pages`` are [L, P, page_size, N, hd] pools
    shared by every slot; a host-side page table maps slot -> page ids.
    With HDP enabled an int8 ``k_scout`` pool rides along — the
    write-time-quantized integer copy of K that the decode scout always
    streams, so the full-precision K/V of pruned pages is never gathered
    (the Fetch-Upon-Mask contract; see
    ``attention.hdp_paged_decode_attention``).

    Page 0 is a reserved *scratch* page: pruned pages' gather indices and
    inactive slots' decode writes are redirected there, so its contents
    are arbitrary-but-finite and, by construction, always masked.

    Pages are allocated per request for ``prompt + max_new`` tokens (not
    ``max_len``), which is where the serving-memory win over the dense
    per-slot layout comes from; ``active_bytes`` tracks it.
    """

    def __init__(self, cfg, batch: int, max_len: int,
                 page_size: Optional[int] = None, num_pages: Optional[int] = None):
        hdp = cfg.hdp
        self.scout = hdp is not None and hdp.enabled
        ps = page_size or (hdp.block_k if self.scout else 16)
        if self.scout and ps != hdp.block_k:
            raise ValueError(
                f"page_size {ps} must equal hdp.block_k {hdp.block_k} so "
                "pages coincide with the scout's pruning blocks")
        if self.scout and hdp.int_bits > 6:
            raise ValueError(
                f"int_bits={hdp.int_bits} exceeds the int8 scout copy's "
                "range (integer parts reach +/-2^int_bits; need <= 6)")
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.page_size = ps
        self.pages_per_slot = -(-max_len // ps)
        self.num_pages = (1 + batch * self.pages_per_slot
                          if num_pages is None else num_pages)
        L, N, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        dt = jnp.dtype(cfg.dtype)
        shape = (L, self.num_pages, ps, N, hd)
        self.cache: Dict[str, jnp.ndarray] = {
            "k_pages": jnp.zeros(shape, dt),
            "v_pages": jnp.zeros(shape, dt),
        }
        if self.scout:
            self.cache["k_scout"] = jnp.zeros(shape, jnp.int8)
        self._free: List[int] = list(range(1, self.num_pages))
        self._slot_pages: Dict[int, List[int]] = {}
        self._table = np.zeros((batch, self.pages_per_slot), np.int32)
        self._table_dev: Optional[jnp.ndarray] = None
        self.peak_pages = 0

    # ---------------------------------------------------------- host state
    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self._slot_pages.values())

    def table(self) -> jnp.ndarray:
        """Device copy of the page table, re-uploaded only after
        alloc/free mutate it (steady-state decode uploads nothing)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    def alloc(self, slot: int, n_tokens: int) -> List[int]:
        """Reserve pages for `n_tokens` cache positions of `slot`."""
        if slot in self._slot_pages:
            self.free(slot)
        need = max(1, -(-n_tokens // self.page_size))
        if need > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed max_len {self.max_len}")
        if need > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {need}, free {len(self._free)}")
        pages = [self._free.pop(0) for _ in range(need)]
        self._slot_pages[slot] = pages
        self._table[slot, :] = 0
        self._table[slot, :need] = pages
        self._table_dev = None
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return pages

    def free(self, slot: int) -> None:
        # returned pages go to the FRONT: the next allocation reuses the
        # hottest pages, which also makes reuse deterministic to test
        self._free[:0] = self._slot_pages.pop(slot, [])
        self._table[slot, :] = 0
        self._table_dev = None

    # -------------------------------------------------------------- insert
    def insert(self, one_cache, slot: int, row: int = 0) -> None:
        """Scatter row `row` of a prefill cache into `slot`'s pages.

        Prefill positions past the slot's allocation are bucket padding —
        causally dead and overwritten by decode before they are ever
        visible — so they are simply dropped."""
        pages = self._slot_pages[slot]
        ps = self.page_size
        k = one_cache["k"][:, row]                     # [L, S, N, hd]
        v = one_cache["v"][:, row]
        L, S, N, hd = k.shape
        npg = min(-(-S // ps), len(pages))
        pad = npg * ps - min(S, npg * ps)

        def to_pages(x):
            x = x[:, :npg * ps]
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x.reshape(L, npg, ps, N, hd)

        idx = jnp.asarray(pages[:npg], jnp.int32)
        kp, vp = to_pages(k), to_pages(v)
        self.cache["k_pages"] = self.cache["k_pages"].at[:, idx].set(
            kp.astype(self.cache["k_pages"].dtype))
        self.cache["v_pages"] = self.cache["v_pages"].at[:, idx].set(
            vp.astype(self.cache["v_pages"].dtype))
        if self.scout:
            from repro.models.attention import scout_int8
            self.cache["k_scout"] = self.cache["k_scout"].at[:, idx].set(
                scout_int8(kp, self.cfg.hdp))

    # ------------------------------------------------------------ metrics
    def _page_bytes(self) -> int:
        per = sum(v.dtype.itemsize * int(np.prod(v.shape[2:]))
                  for v in self.cache.values()) * self.cfg.n_layers
        return per

    def active_bytes(self, pages: Optional[int] = None) -> int:
        """Bytes resident for `pages` allocated pages (default: current)."""
        n = self.pages_in_use if pages is None else pages
        return n * self._page_bytes()

    def pool_bytes(self) -> int:
        return cache_bytes(self.cache)


def kv_read_bytes_per_step(cfg, seq_len: int, batch: int,
                           hdp_block_sparsity: float = 0.0) -> Tuple[int, int]:
    """(dense, hdp) HBM bytes read from the KV cache per decode step.

    The FUM accounting: pruned KV blocks are never fetched, so HDP decode
    reads ``(1 - sparsity)`` of K/V (the int8 scout copy of K always
    streams). Used by the roofline benchmarks.
    """
    if not hasattr(cfg, "n_kv_heads") or cfg.n_kv_heads == 0:
        return 0, 0
    itemsize = jnp.dtype(cfg.dtype).itemsize
    layers = cfg.n_layers
    kv = 2 * layers * batch * seq_len * cfg.n_kv_heads * cfg.hd * itemsize
    scout = layers * batch * seq_len * cfg.n_kv_heads * cfg.hd  # int8 K
    hdp = int(scout + (1.0 - hdp_block_sparsity) * kv)
    return int(kv), hdp
