"""KV-cache slot management for batched serving.

The decode cache for every family is a pytree whose leaves carry a
``batch`` axis (its index per leaf comes from ``registry.cache_specs``).
`SlotCache` provides:

* ``insert(batch_cache, one_cache, slot)`` — copy a freshly-prefilled
  single-request cache (batch=1, possibly shorter ``max_len``) into slot
  ``slot`` of the serving batch cache (jit-compatible: slot is traced);
* ``clear(batch_cache, slot)`` — zero a slot on request completion;
* ``lengths`` bookkeeping lives in the engine (host side).

HDP interaction: the decode path prunes KV *blocks* per query on the fly
(`hdp_decode_attention`); the cache layout is unchanged — pruning decides
which pages are *read*, which is the FUM memory-traffic win, not which
are stored.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import registry


def _batch_axes(cfg) -> Any:
    """Cache-structured tree of the batch-axis index per leaf."""
    specs = registry.cache_specs(cfg)

    def one(ax):
        ax = tuple(ax)
        return ax.index("batch") if "batch" in ax else None

    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


class SlotCache:
    """Slot arithmetic over a family-agnostic cache pytree."""

    def __init__(self, cfg, batch: int, max_len: int, **cache_kw):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.cache = registry.init_cache(cfg, batch, max_len=max_len,
                                         **cache_kw)
        self.axes = _batch_axes(cfg)

    # ------------------------------------------------------------- insert
    def insert(self, one_cache, slot) -> None:
        """Copy a batch=1 request cache into `slot` (in place on host)."""
        self.cache = insert_slot(self.cache, one_cache, slot, self.axes)

    def clear(self, slot) -> None:
        self.cache = clear_slot(self.cache, slot, self.axes)


def _dus_axis(big, small, slot, axis: int):
    """dynamic_update_slice of `small` into `big` at index `slot` of `axis`,
    zero-padding the sequence dims when the prefill cache is shorter."""
    if small.shape[axis] != 1:
        small = jnp.take(small, jnp.arange(1), axis=axis)  # defensive
    # pad every non-batch dim that is shorter (bucketed prefill caches)
    pads = []
    for d, (bs, ss) in enumerate(zip(big.shape, small.shape)):
        if d == axis:
            pads.append((0, 0))
        else:
            if ss > bs:
                raise ValueError(
                    f"request cache dim {d} ({ss}) exceeds serving cache "
                    f"({bs})")
            pads.append((0, bs - ss))
    small = jnp.pad(small, pads)
    start = [jnp.asarray(0, jnp.int32)] * big.ndim
    start[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)


def insert_slot(batch_cache, one_cache, slot, axes) -> Any:
    def one(big, small, ax):
        if ax is None:  # no batch axis (shared leaf) — keep serving copy
            return big
        return _dus_axis(big, small, slot, ax)

    return jax.tree.map(one, batch_cache, one_cache, axes)


def clear_slot(batch_cache, slot, axes) -> Any:
    def one(big, ax):
        if ax is None:
            return big
        shape = list(big.shape)
        shape[ax] = 1
        return _dus_axis(big, jnp.zeros(shape, big.dtype), slot, ax)

    return jax.tree.map(one, batch_cache, axes)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def kv_read_bytes_per_step(cfg, seq_len: int, batch: int,
                           hdp_block_sparsity: float = 0.0) -> Tuple[int, int]:
    """(dense, hdp) HBM bytes read from the KV cache per decode step.

    The FUM accounting: pruned KV blocks are never fetched, so HDP decode
    reads ``(1 - sparsity)`` of K/V (the int8 scout copy of K always
    streams). Used by the roofline benchmarks.
    """
    if not hasattr(cfg, "n_kv_heads") or cfg.n_kv_heads == 0:
        return 0, 0
    itemsize = jnp.dtype(cfg.dtype).itemsize
    layers = cfg.n_layers
    kv = 2 * layers * batch * seq_len * cfg.n_kv_heads * cfg.hd * itemsize
    scout = layers * batch * seq_len * cfg.n_kv_heads * cfg.hd  # int8 K
    hdp = int(scout + (1.0 - hdp_block_sparsity) * kv)
    return int(kv), hdp
