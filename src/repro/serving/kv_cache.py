"""KV-cache management for batched serving: dense slots and block pages.

Two layouts, selected by the engine's ``cache_backend``:

* `SlotCache` — the dense per-slot contiguous layout. The decode cache
  for every family is a pytree whose leaves carry a ``batch`` axis (its
  index per leaf comes from ``registry.cache_specs``); ``insert`` copies
  one row of a freshly-prefilled request cache (possibly shorter
  ``max_len`` — bucketed/batched prefill) into a slot, ``clear`` zeroes a
  slot on completion. Works for every family, including recurrent state.
* `PagedKVCache` — the block-paged transformer layout: one shared page
  pool + per-slot page tables, page size = HDP's ``block_k`` so cache
  pages coincide with the integer scout's pruning blocks. The decode
  path gathers only scout-surviving pages (`hdp_paged_decode_attention`)
  — pruned pages are never read, which is the FUM memory-traffic win —
  and pages are allocated per request, which is the resident-bytes win.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (POISON_CODE, absmax_page_scale, encode_pool,
                              encode_pool_scaled, pool_int_bits, pool_scale)
from repro.models import registry
from repro.serving.allocator import PageAllocator

#: storage formats of the paged pool: int8 codes + per-page scale (the
#: production default), int8 K + fp8 V, or the fp32 A/B oracle.
KV_DTYPES = ("fp32", "int8", "fp8_v")

#: scale calibration of a quantized pool: the static power-of-two grid
#: (bit-parity guarantees) or opt-in per-page calibrated absmax scales.
KV_SCALES = ("grid", "absmax")


class DonatedCacheError(RuntimeError):
    """The live cache handle was donated to a jitted step and not replaced.

    Raised instead of letting XLA hit a deleted buffer: with
    ``donate_argnums`` the decode step aliases the page pool in place, so
    the pre-call handle is dead the moment the call is dispatched.
    Callers must bracket donating calls with ``take()`` / ``put()``.
    """


class _DonatableCache:
    """Mixin guarding the ``cache`` attribute across buffer donation."""

    _cache: Any = None

    @property
    def cache(self):
        if self._cache is None:
            raise DonatedCacheError(
                "KV cache handle was donated to a jitted decode step and "
                "not yet replaced; bracket donating calls with take()/put()")
        return self._cache

    @cache.setter
    def cache(self, value) -> None:
        self._cache = value

    @property
    def donated(self) -> bool:
        """True while the handle is checked out (``take()`` without a
        matching ``put()``/``restore_if_undonated``) — fault-path tests
        assert this is False after an exception unwinds a decode step."""
        return self._cache is None

    def take(self):
        """Hand the live cache out for a donating call; the stored handle
        becomes invalid until ``put`` installs the aliased output."""
        c = self.cache
        self._cache = None
        return c

    def put(self, new_cache) -> None:
        if self._cache is not None:
            raise DonatedCacheError("put() without a prior take()")
        self._cache = new_cache

    def restore_if_undonated(self, cache) -> None:
        """After a failed donating call: re-install the handle unless XLA
        actually consumed (deleted) the donated buffers — the one place
        the donation-detection heuristic lives."""
        if not any(getattr(x, "is_deleted", lambda: False)()
                   for x in jax.tree.leaves(cache)):
            self.put(cache)

    def _donating(self, fn, *args):
        """Run a cache-donating jit with take()/put() bracketing; on a
        trace/compile failure the untouched handle is restored so the
        real error surfaces instead of a later DonatedCacheError."""
        c = self.take()
        try:
            new = fn(c, *args)
        except BaseException:
            self.restore_if_undonated(c)
            raise
        self.put(new)


def _batch_axes(cfg) -> Any:
    """Cache-structured tree of the batch-axis index per leaf."""
    specs = registry.cache_specs(cfg)

    def one(ax):
        ax = tuple(ax)
        return ax.index("batch") if "batch" in ax else None

    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


class SlotCache(_DonatableCache):
    """Slot arithmetic over a family-agnostic cache pytree.

    ``insert``/``clear`` run through donated jits: the serving cache is
    aliased in place instead of re-materialized per call (the same
    zero-copy contract the decode loop has; stale handles raise
    ``DonatedCacheError`` through ``take()``/``put()``).
    """

    def __init__(self, cfg, batch: int, max_len: int, **cache_kw):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.cache = registry.init_cache(cfg, batch, max_len=max_len,
                                         **cache_kw)
        self.axes = _batch_axes(cfg)
        self._ins_jit = jax.jit(
            lambda c, one, slot, row: insert_slot(c, one, slot, self.axes,
                                                  row=row),
            donate_argnums=(0,))
        self._clr_jit = jax.jit(
            lambda c, slot: clear_slot(c, slot, self.axes),
            donate_argnums=(0,))

    # ------------------------------------------------------------- insert
    def insert(self, one_cache, slot, row: int = 0) -> None:
        """Copy row `row` of a request cache into `slot` (donated, in place)."""
        self._donating(self._ins_jit, one_cache,
                       jnp.asarray(slot, jnp.int32), jnp.asarray(row, jnp.int32))

    def clear(self, slot) -> None:
        self._donating(self._clr_jit, jnp.asarray(slot, jnp.int32))


def _dus_axis(big, small, slot, axis: int, row: int = 0):
    """dynamic_update_slice of row `row` of `small` into `big` at index
    `slot` of `axis`, zero-padding the sequence dims when the prefill cache
    is shorter (bucketed/batched prefill)."""
    if small.shape[axis] != 1:
        small = jax.lax.dynamic_slice_in_dim(small, row, 1, axis)
    # pad every non-batch dim that is shorter (bucketed prefill caches)
    pads = []
    for d, (bs, ss) in enumerate(zip(big.shape, small.shape)):
        if d == axis:
            pads.append((0, 0))
        else:
            if ss > bs:
                raise ValueError(
                    f"request cache dim {d} ({ss}) exceeds serving cache "
                    f"({bs})")
            pads.append((0, bs - ss))
    small = jnp.pad(small, pads)
    start = [jnp.asarray(0, jnp.int32)] * big.ndim
    start[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)


def insert_slot(batch_cache, one_cache, slot, axes, row: int = 0) -> Any:
    def one(big, small, ax):
        if ax is None:  # no batch axis (shared leaf) — keep serving copy
            return big
        return _dus_axis(big, small, slot, ax, row=row)

    return jax.tree.map(one, batch_cache, one_cache, axes)


def clear_slot(batch_cache, slot, axes) -> Any:
    def one(big, ax):
        if ax is None:
            return big
        shape = list(big.shape)
        shape[ax] = 1
        return _dus_axis(big, jnp.zeros(shape, big.dtype), slot, ax)

    return jax.tree.map(one, batch_cache, axes)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


# --------------------------------------------------------------------------
# Block-paged KV cache (transformer families)
# --------------------------------------------------------------------------
class PagedKVCache(_DonatableCache):
    """Page pool + per-slot page tables, aligned to HDP's ``block_k``.

    Layout: ``k_pages``/``v_pages`` are [L, P, page_size, N, hd] pools
    shared by every slot; a host-side page table maps slot -> page ids.

    ``kv_dtype`` selects the pool storage format:

    * ``"int8"`` (default) — K and V stored as int8 codes on the static
      power-of-two grid (``core.quant.pool_scale``), with per-page
      (per-kv-head) scale arrays ``k_scale``/``v_scale`` [L, P, N]
      written at insert/COW time. The integer and quantized-fraction
      scout copies the decode scout and the self-speculative draft
      stream are *derived views of the codes* (``pool_view_finite``) —
      no extra pools — so drafts, prefix-cached pages and COW tails all
      share one quantized store, and resident cache bytes drop ~4x.
      Dequant is fused into the consumers (gather-time in the XLA
      page-chunk scan, in-register in the Pallas FUM kernel), so pruned
      pages still never DMA.
    * ``"fp8_v"`` — int8 K as above, V stored as float8_e4m3fn (scale
      1.0: the fp8 exponent replaces the per-page scale's job).
    * ``"fp32"`` — the full-precision pool, demoted to an opt-in A/B
      oracle. With HDP enabled an int8 ``k_scout`` pool rides along —
      the write-time-quantized integer copy of K that the decode scout
      always streams, so the full-precision K/V of pruned pages is never
      gathered (the Fetch-Upon-Mask contract; see
      ``attention.hdp_paged_decode_attention``).

    Page 0 is a reserved *scratch* page: pruned pages' gather indices and
    inactive slots' decode writes are redirected there, so its contents
    are arbitrary-but-FINITE and, by construction, always masked. The
    finiteness is load-bearing for K as well as V: an early-head-gated
    head never fetches its pages (gathers read scratch in their place)
    but still runs its softmax before the gate zeroes the output, so
    NaN in scratch K would become NaN * 0 = NaN through the gate — which
    is why the speculative rollback poison explicitly skips the scratch
    page while freed-page poison (never a gather target) is safe.

    Page *ownership* lives in ``self.allocator`` (a refcounted
    `allocator.PageAllocator`): one physical page can back several slots
    plus the prefix cache, and returns to the free list only on its last
    ``unref``. ``assign`` installs an externally-built page list (shared
    prefix pages + owned pages) into a slot's table row; ``alloc`` is the
    allocate-fresh-and-assign convenience the non-sharing paths use.

    Pages are allocated per request for ``prompt + max_new`` tokens (not
    ``max_len``), which is where the serving-memory win over the dense
    per-slot layout comes from; ``active_bytes`` tracks it. All pool
    mutations (``insert``, ``cow``) run through donated jits — the pool
    is aliased in place, never copied per call.

    ``poison_freed`` (debug): poison a page's full-precision K on *true
    free only* — a stale unmasked read of a freed page then surfaces as
    NaN in the scores, while a page still shared by any owner is never
    poisoned. fp32 pools write NaN into ``k_pages``; quantized pools
    write a NaN *sentinel scale* instead (poison must survive
    quantization — NaN has no int8 code), which poisons every stage-3
    dequant of the page while the static-grid scout views stay finite
    (mirroring fp32, where the scout copies were never poisoned). K-only
    either way: V of positions the mask excludes is multiplied by an
    exact 0 but still *read* by XLA, so V-poison would leak NaN through
    legitimate masked reads of reused pages. Reused pages recover their
    scale on first write (insert scatter or the decode scatter's
    scale refresh).
    """

    def __init__(self, cfg, batch: int, max_len: int,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 poison_freed: bool = False,
                 draft_scout: bool = False,
                 kv_dtype: str = "int8",
                 kv_scale: str = "grid",
                 mesh: Optional[jax.sharding.Mesh] = None):
        hdp = cfg.hdp
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
        if kv_scale not in KV_SCALES:
            raise ValueError(
                f"kv_scale must be one of {KV_SCALES}, got {kv_scale!r}")
        if kv_scale == "absmax" and kv_dtype == "fp32":
            raise ValueError(
                "kv_scale='absmax' calibrates a quantized pool's scales; "
                "fp32 pools have none (use kv_dtype='int8'/'fp8_v')")
        self.kv_dtype = kv_dtype
        self.kv_scale = kv_scale
        self.quantized = kv_dtype != "fp32"
        self.scout = hdp is not None and hdp.enabled
        #: fp32 pools also store the int8 quantized-fraction copy of K at
        #: write time (``f_scout``) when asked: the self-speculative
        #: draft reconstructs its scores from the two int8 copies alone,
        #: so draft steps never read the full-precision K pool. Only
        #: allocated on request — non-speculating engines pay no extra
        #: pool memory. Quantized pools derive both scout copies from
        #: the codes instead, so the flag allocates nothing there.
        self.draft_scout = draft_scout and self.scout
        ps = page_size or (hdp.block_k if self.scout else 16)
        if self.scout and ps != hdp.block_k:
            raise ValueError(
                f"page_size {ps} must equal hdp.block_k {hdp.block_k} so "
                "pages coincide with the scout's pruning blocks")
        if (self.scout or self.quantized) and hdp is not None \
                and hdp.enabled and hdp.int_bits > 6:
            raise ValueError(
                f"int_bits={hdp.int_bits} exceeds the int8 scout copy's "
                "range (integer parts reach +/-2^int_bits; need <= 6)")
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.page_size = ps
        self.pages_per_slot = -(-max_len // ps)
        self.num_pages = (1 + batch * self.pages_per_slot
                          if num_pages is None else num_pages)
        self.poison_freed = poison_freed
        self.int_bits = pool_int_bits(hdp)
        L, N, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        dt = jnp.dtype(cfg.dtype)
        shape = (L, self.num_pages, ps, N, hd)
        if self.quantized:
            v_dt = jnp.dtype(jnp.float8_e4m3fn) if kv_dtype == "fp8_v" \
                else jnp.dtype(jnp.int8)
            s0 = pool_scale(self.int_bits)
            self.cache: Dict[str, jnp.ndarray] = {
                "k_pages": jnp.zeros(shape, jnp.int8),
                "v_pages": jnp.zeros(shape, v_dt),
                # per-page per-kv-head scales; the scratch page's stays
                # the static grid scale forever (finite by contract)
                "k_scale": jnp.full((L, self.num_pages, N), s0, jnp.float32),
                "v_scale": jnp.full((L, self.num_pages, N),
                                    1.0 if kv_dtype == "fp8_v" else s0,
                                    jnp.float32),
            }
        else:
            self.cache = {
                "k_pages": jnp.zeros(shape, dt),
                "v_pages": jnp.zeros(shape, dt),
            }
            if self.scout:
                self.cache["k_scout"] = jnp.zeros(shape, jnp.int8)
            if self.draft_scout:
                self.cache["f_scout"] = jnp.zeros(shape, jnp.int8)
        self.mesh = mesh
        self.tp = 1
        if mesh is not None:
            from repro.distribution.tp import pool_shardings
            self.tp = int(dict(mesh.shape).get("model", 1))
            if N % self.tp != 0:
                raise ValueError(
                    f"n_kv_heads={N} not divisible by tp={self.tp}")
            # resident pool lives head-sharded: each model shard holds
            # 1/tp of every page's codes, scales and scout views
            self.cache = jax.device_put(
                self.cache, pool_shardings(mesh, self.cache))
        self.allocator = PageAllocator(self.num_pages, reserved=1,
                                       on_free=self._on_free)
        self._slot_pages: Dict[int, List[int]] = {}
        self._slot_floor: Dict[int, int] = {}
        self._table = np.zeros((batch, self.pages_per_slot), np.int32)
        self._table_dev: Optional[jnp.ndarray] = None
        self.peak_pages = 0
        self._insert_jit = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._cow_jit = jax.jit(self._cow_fn, donate_argnums=(0,))
        self._gather_jit = jax.jit(self._gather_fn)

    # ---------------------------------------------------------- host state
    @property
    def _free(self) -> List[int]:
        """Free-list view (read-only; kept for tests/introspection)."""
        return self.allocator._free

    @property
    def pages_in_use(self) -> int:
        """Distinct live pages — slot-owned, shared, or prefix-cached."""
        return self.allocator.in_use

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages.get(slot, []))

    def first_owned(self, slot: int) -> int:
        """Index of the first slot-owned (writable) page in the table row;
        earlier entries are shared read-only prefix pages."""
        return self._slot_floor.get(slot, 0)

    def table(self) -> jnp.ndarray:
        """Device copy of the page table, re-uploaded only after
        alloc/free mutate it (steady-state decode uploads nothing)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    def assign(self, slot: int, pages: List[int], first_owned: int = 0) -> None:
        """Install `pages` (each already holding one ref owned by this
        slot) as the slot's table row; entries before `first_owned` are
        shared read-only prefix pages the decode write path must never
        touch (enforced by the write floor threaded into the decode jit).
        """
        if slot in self._slot_pages:
            self.free(slot)
        if len(pages) > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {len(pages)} pages exceed table width "
                f"{self.pages_per_slot}")
        self._slot_pages[slot] = list(pages)
        self._slot_floor[slot] = first_owned
        self._table[slot, :] = 0
        self._table[slot, :len(pages)] = pages
        self._table_dev = None
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        if self.poison_freed and self.quantized and pages:
            # a quantized pool's freed-page poison is the NaN sentinel
            # scale; revive it the moment the page re-enters a table row
            # (insert/COW also rewrite it, but decode-growth pages are
            # first touched by the scatter, which writes codes only)
            idx = jnp.asarray(pages, jnp.int32)
            s0 = pool_scale(self.int_bits)
            self.cache = {**self.cache,
                          "k_scale": self.cache["k_scale"].at[:, idx].set(s0)}

    def alloc(self, slot: int, n_tokens: int) -> List[int]:
        """Reserve fresh pages for `n_tokens` cache positions of `slot`."""
        if slot in self._slot_pages:
            self.free(slot)
        need = max(1, -(-n_tokens // self.page_size))
        if need > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed max_len {self.max_len}")
        pages = self.allocator.alloc(need)
        self.assign(slot, pages)
        return pages

    def free(self, slot: int) -> None:
        """Release the slot's refs; pages truly free only when unshared."""
        self.allocator.unref(self._slot_pages.pop(slot, []))
        self._slot_floor.pop(slot, None)
        self._table[slot, :] = 0
        self._table_dev = None

    def _on_free(self, pages: List[int]) -> None:
        if self.poison_freed and pages:
            idx = jnp.asarray(pages, jnp.int32)
            if self.quantized:
                # NaN has no int8 code — poison travels through the
                # per-page sentinel scale (every dequant of the page goes
                # NaN; the static-grid scout views stay finite, same as
                # the fp32 pools' unpoisoned scout copies)
                self.cache = {**self.cache,
                              "k_scale": self.cache["k_scale"].at[
                                  :, idx].set(jnp.nan)}
            else:
                self.cache = {**self.cache,
                              "k_pages": self.cache["k_pages"].at[:, idx].set(
                                  jnp.nan)}

    def poison_view(self) -> np.ndarray:
        """Elementwise poison marks of K, shaped like ``k_pages`` — the
        dtype-independent introspection the debug tests assert on (NaN
        under fp32; the -128 sentinel code or a NaN page scale under a
        quantized pool)."""
        kp = np.asarray(self.cache["k_pages"])
        if not self.quantized:
            return np.isnan(kp)
        scl = np.isnan(np.asarray(self.cache["k_scale"]))  # [L, P, N]
        return (kp == POISON_CODE) | scl[:, :, None, :, None]

    # -------------------------------------------------------------- insert
    def _row_to_pages(self, k, row, npg):
        """[L, B, S, N, hd] row -> [L, npg, ps, N, hd] page-shaped."""
        L, _, S, N, hd = k.shape
        ps = self.page_size
        kr = jax.lax.dynamic_index_in_dim(k, row, 1, keepdims=False)
        pad = npg * ps - S
        if pad > 0:
            kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return kr[:, :npg * ps].reshape(L, npg, ps, N, hd)

    def _insert_fn(self, pool, k, v, idx, row):
        """Scatter one request-cache row into the (donated) pool.

        `idx` [pages_per_slot] holds the destination pool page per cache
        page; entries of 0 redirect to the scratch page, which absorbs
        bucket padding and the shared-prefix span without touching any
        real page (scratch content stays arbitrary-but-finite). The
        scatter covers only the pages the request cache can fill
        (ceil(S / ps), a static shape) — a short bucket does not pay a
        pages_per_slot-wide write.
        """
        npg = min(-(-k.shape[2] // self.page_size), self.pages_per_slot)
        kp = self._row_to_pages(k, row, npg)
        vp = self._row_to_pages(v, row, npg)
        flat = idx[:npg].astype(jnp.int32)
        if self.quantized:
            s0 = pool_scale(self.int_bits)
            if self.kv_scale == "absmax":
                # per-page calibrated scales: s = max|x|/127 over the
                # page's positions and head dim, per kv head (all-zero
                # pages fall back to the static step — finite, nonzero)
                ks = absmax_page_scale(kp, self.int_bits)    # [L, npg, N]
                kq = encode_pool_scaled(kp, ks[:, :, None, :, None])
            else:
                ks = jnp.full(kp.shape[:2] + kp.shape[3:4], s0, jnp.float32)
                kq = encode_pool(kp, self.int_bits)
            if self.kv_dtype == "fp8_v":
                vq = vp.astype(pool["v_pages"].dtype)
                vs = jnp.ones_like(ks)
            elif self.kv_scale == "absmax":
                vs = absmax_page_scale(vp, self.int_bits)
                vq = encode_pool_scaled(vp, vs[:, :, None, :, None])
            else:
                vs = jnp.full_like(ks, s0)
                vq = encode_pool(vp, self.int_bits)
            # scales are (re)written with the codes, so a reused page
            # sheds any freed-poison sentinel the moment it holds data
            return {
                "k_pages": pool["k_pages"].at[:, flat].set(kq),
                "v_pages": pool["v_pages"].at[:, flat].set(vq),
                "k_scale": pool["k_scale"].at[:, flat].set(ks),
                "v_scale": pool["v_scale"].at[:, flat].set(vs),
            }
        new = {
            "k_pages": pool["k_pages"].at[:, flat].set(
                kp.astype(pool["k_pages"].dtype)),
            "v_pages": pool["v_pages"].at[:, flat].set(
                vp.astype(pool["v_pages"].dtype)),
        }
        if self.scout:
            from repro.models.attention import scout_int8
            new["k_scout"] = pool["k_scout"].at[:, flat].set(
                scout_int8(kp, self.cfg.hdp))
        if self.draft_scout:
            from repro.models.attention import scout_frac_int8
            new["f_scout"] = pool["f_scout"].at[:, flat].set(
                scout_frac_int8(kp, self.cfg.hdp))
        return new

    def insert(self, one_cache, slot: int, row: int = 0,
               first_page: int = 0) -> None:
        """Scatter row `row` of a prefill cache into `slot`'s pages.

        Prefill positions past the slot's allocation are bucket padding —
        causally dead and overwritten by decode before they are ever
        visible — and cache pages before `first_page` (a shared prefix
        gathered from the pool, already resident) must not be rewritten:
        both are redirected to the scratch page."""
        pages = self._slot_pages[slot]
        idx = np.zeros(self.pages_per_slot, np.int32)
        idx[first_page:len(pages)] = pages[first_page:]
        self._donating(self._insert_jit, one_cache["k"], one_cache["v"],
                       jnp.asarray(idx), jnp.asarray(row, jnp.int32))

    # ----------------------------------------------------- prefix sharing
    def _cow_fn(self, pool, src, dst):
        return {name: leaf.at[:, dst].set(leaf[:, src])
                for name, leaf in pool.items()}

    def cow(self, src: int, dst: int) -> None:
        """Copy-on-write: duplicate page `src` into owned page `dst`
        (all pools, scout copy included) through the donated pool."""
        self._donating(self._cow_jit, jnp.asarray(src, jnp.int32),
                       jnp.asarray(dst, jnp.int32))

    def _gather_fn(self, pool, idx):
        """Pool pages -> contiguous [L, 1, max_len, N, hd] request cache.

        Positions past the real prefix read the scratch page: arbitrary
        but finite, and masked to an exact-zero contribution by every
        attention path (same contract as bucket padding). Quantized
        pools dequantize here — the request cache a prefix hit seeds
        holds exactly the round-tripped values a cold prefill writes, so
        hot and cold runs stay token-identical."""
        kp = pool["k_pages"]
        L, _, ps, N, hd = kp.shape

        def to_cache(codes, scale):
            g = codes[:, idx]                       # [L, nP, ps, N, hd]
            if scale is not None:
                g = g.astype(jnp.float32) * scale[:, idx][:, :, None, :, None]
            g = g.reshape(L, self.pages_per_slot * ps, N, hd)
            return g[:, None, :self.max_len]

        if self.quantized:
            # prefix pages are live (never freed-poisoned) and hold no
            # rejected-write sentinels (verify rewrites staged positions
            # before a page can enter the prefix cache), so the plain
            # codes * scale dequant is exact here
            return {"k": to_cache(kp, pool["k_scale"]),
                    "v": to_cache(pool["v_pages"], pool["v_scale"])}
        return {"k": to_cache(kp, None), "v": to_cache(pool["v_pages"], None)}

    def gather_prefix(self, pages: List[int]) -> Dict[str, jnp.ndarray]:
        """Build a request cache seeded with the shared prefix pages —
        the cache the suffix-only chunked prefill then appends to."""
        idx = np.zeros(self.pages_per_slot, np.int32)
        idx[:len(pages)] = pages
        return self._gather_jit(self.cache, jnp.asarray(idx))

    # ------------------------------------------------------------ metrics
    def _page_bytes(self) -> int:
        per = sum(v.dtype.itemsize * int(np.prod(v.shape[2:]))
                  for v in self.cache.values()) * self.cfg.n_layers
        return per

    def active_bytes(self, pages: Optional[int] = None) -> int:
        """Bytes resident for `pages` allocated pages (default: current)."""
        n = self.pages_in_use if pages is None else pages
        return n * self._page_bytes()

    def bytes_per_token(self) -> float:
        """Resident pool bytes per cached token, over every pool leaf
        (codes + per-page scales + any scout copies) — the
        dtype-sensitive footprint the serving summary reports."""
        return self._page_bytes() / self.page_size

    def pool_bytes(self) -> int:
        return cache_bytes(self.cache)

    def pool_bytes_per_shard(self) -> int:
        """Resident pool bytes held by ONE model shard: every pool leaf
        (codes, scales, scout views) is head-sharded, so each of the tp
        shards holds exactly 1/tp of the pool."""
        return self.pool_bytes() // self.tp


def kv_read_bytes_per_step(cfg, seq_len: int, batch: int,
                           hdp_block_sparsity: float = 0.0) -> Tuple[int, int]:
    """(dense, hdp) HBM bytes read from the KV cache per decode step.

    The FUM accounting: pruned KV blocks are never fetched, so HDP decode
    reads ``(1 - sparsity)`` of K/V (the int8 scout copy of K always
    streams). Used by the roofline benchmarks.
    """
    if not hasattr(cfg, "n_kv_heads") or cfg.n_kv_heads == 0:
        return 0, 0
    itemsize = jnp.dtype(cfg.dtype).itemsize
    layers = cfg.n_layers
    kv = 2 * layers * batch * seq_len * cfg.n_kv_heads * cfg.hd * itemsize
    scout = layers * batch * seq_len * cfg.n_kv_heads * cfg.hd  # int8 K
    hdp = int(scout + (1.0 - hdp_block_sparsity) * kv)
    return int(kv), hdp
