"""Batched serving engine with HDP: prefill/decode, continuous batching.

The engine keeps a fixed pool of ``max_batch`` decode slots. New requests
are prefilled one at a time (prompt padded up to the nearest *bucket* so
the prefill jit-cache stays small), their KV/state cache inserted into a
free slot, and the batched decode step advances every active slot with
its own position (per-slot positions thread through
``attention.attn_apply``). Finished slots (EOS or per-request token
budget) are freed and immediately refillable — continuous batching.

HDP is active inside both prefill and decode attention when
``cfg.hdp.enabled`` — stats (block/head sparsity per layer) are
aggregated into engine metrics so serving examples/benchmarks can report
the achieved sparsity next to throughput.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import kv_cache

I32 = jnp.int32


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Result:
    uid: int
    prompt_len: int
    tokens: List[int]
    prefill_s: float = 0.0
    decode_steps: int = 0


def _buckets(lens: Sequence[int]) -> Sequence[int]:
    out = sorted(set(lens))
    return out


class Engine:
    """Single-host serving engine (mesh-aware variants run via launch/serve).

    Parameters
    ----------
    cfg: ModelConfig (reduced configs run on CPU).
    params: model params; freshly initialized when None.
    max_batch: decode slot count.
    max_len: serving cache length (prompt + generation must fit).
    prefill_buckets: pad-to lengths for the prefill jit cache.
    collect_stats: aggregate HDP sparsity stats (small overhead).
    """

    def __init__(self, cfg: ModelConfig, params=None, *, rng=None,
                 max_batch: int = 4, max_len: int = 128,
                 prefill_buckets: Sequence[int] = (32, 64, 128),
                 collect_stats: bool = False):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "enc-dec serving uses launch/serve.py --arch whisper path")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = sorted(b for b in prefill_buckets if b <= max_len) \
            or [max_len]
        self.collect_stats = collect_stats

        if params is None:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            params, _ = registry.init_params(cfg, rng)
        self.params = params

        self.slots = kv_cache.SlotCache(cfg, max_batch, max_len)
        self._free = list(range(max_batch))
        self._active: Dict[int, Dict[str, Any]] = {}  # slot -> request state
        self._results: Dict[int, Result] = {}
        self._queue: List[Request] = []
        self._last_tok = jnp.zeros((max_batch, 1), I32)
        self._pos = jnp.zeros((max_batch,), I32)
        self.metrics: Dict[str, float] = {
            "prefill_s": 0.0, "decode_s": 0.0, "decode_steps": 0,
            "tokens_out": 0, "block_sparsity": 0.0, "head_sparsity": 0.0,
            "stat_samples": 0}

        self._prefill_jit = jax.jit(self._prefill_fn, static_argnums=(2,))
        self._decode_jit = jax.jit(self._decode_fn)

    # ------------------------------------------------------------ jitted fns
    def _prefill_fn(self, params, tokens, bucket_len):
        cache = registry.init_cache(self.cfg, 1, max_len=self.max_len)
        batch = {"tokens": tokens}
        logits, new_cache, stats = registry.apply_prefill(
            self.cfg, params, batch, cache,
            collect_stats=self.collect_stats)
        return logits, new_cache, stats

    def _decode_fn(self, params, token, cache, pos):
        logits, new_cache, stats = registry.apply_decode(
            self.cfg, params, token, cache, pos[:, None],
            collect_stats=self.collect_stats)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(I32)[:, None]
        return nxt, new_cache, stats

    # --------------------------------------------------------------- public
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+generation exceeds max_len")
        self._queue.append(req)

    def _bucket_for(self, n: int) -> int:
        if self.cfg.family in ("rwkv6", "zamba2"):
            # recurrent state: prefilling pad tokens would corrupt the
            # SSM state, so these families prefill at exact length
            return n
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_len

    def _admit(self) -> None:
        while self._queue and self._free:
            req = self._queue.pop(0)
            slot = self._free.pop(0)
            t0 = time.perf_counter()
            plen = len(req.prompt)
            bucket = self._bucket_for(plen)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = np.asarray(req.prompt, np.int32)
            # right-pad with the last token (positions beyond plen are
            # overwritten during decode before they are ever attended)
            toks[0, plen:] = toks[0, plen - 1]
            _, one_cache, stats = self._prefill_jit(
                self.params, jnp.asarray(toks), bucket)
            self.slots.insert(one_cache, slot)
            self._record_stats(stats)
            dt = time.perf_counter() - t0
            self.metrics["prefill_s"] += dt
            # uniform resume: the first decode step replays the last prompt
            # token at its own position (its K/V rewrite is idempotent) and
            # yields the first generated token — identical for aligned and
            # bucket-padded prompts.
            self._active[slot] = {"req": req, "generated": []}
            self._results[req.uid] = Result(req.uid, plen, [], prefill_s=dt)
            self._last_tok = self._last_tok.at[slot, 0].set(
                int(req.prompt[-1]))
            self._pos = self._pos.at[slot].set(plen - 1)

    def _record_stats(self, stats) -> None:
        if not self.collect_stats or stats is None:
            return
        try:
            bs = float(jnp.mean(stats["block_sparsity"]))
            hs = float(jnp.mean(stats["head_sparsity"]))
        except (KeyError, TypeError):
            return
        m = self.metrics
        m["block_sparsity"] += bs
        m["head_sparsity"] += hs
        m["stat_samples"] += 1

    def _finish(self, slot: int) -> None:
        st = self._active.pop(slot)
        req = st["req"]
        res = self._results[req.uid]
        res.tokens = st["generated"]
        res.decode_steps = len(st["generated"])
        self.slots.clear(slot)
        self._free.append(slot)

    def step(self) -> int:
        """One engine iteration: admit + one batched decode step.

        Returns the number of active slots stepped."""
        self._admit()
        if not self._active:
            return 0
        t0 = time.perf_counter()
        nxt, new_cache, stats = self._decode_jit(
            self.params, self._last_tok, self.slots.cache, self._pos)
        self.slots.cache = new_cache
        self._record_stats(stats)
        nxt_np = np.asarray(nxt)
        self.metrics["decode_s"] += time.perf_counter() - t0
        self.metrics["decode_steps"] += 1

        self._pos = self._pos + 1
        self._last_tok = nxt
        for slot in list(self._active):
            st = self._active[slot]
            req: Request = st["req"]
            tok = int(nxt_np[slot, 0])
            st["generated"].append(tok)
            self.metrics["tokens_out"] += 1
            done = (len(st["generated"]) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id))
            if done:
                self._finish(slot)
        return len(nxt_np)

    def run(self, max_steps: int = 10_000) -> Dict[int, Result]:
        """Drive until every submitted request completes."""
        steps = 0
        while (self._queue or self._active) and steps < max_steps:
            self.step()
            steps += 1
        return dict(self._results)

    # ------------------------------------------------------------- reporting
    def summary(self) -> Dict[str, float]:
        m = dict(self.metrics)
        if m["decode_s"] > 0:
            m["decode_tok_s"] = m["tokens_out"] / m["decode_s"]
        if m["stat_samples"]:
            m["block_sparsity"] /= m["stat_samples"]
            m["head_sparsity"] /= m["stat_samples"]
        m["cache_bytes"] = kv_cache.cache_bytes(self.slots.cache)
        return m
