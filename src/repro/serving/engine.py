"""Batched serving engine with HDP: paged KV cache, batched prefill,
continuous batching.

The engine keeps a fixed pool of ``max_batch`` decode slots over one of
two cache backends:

* ``paged`` (default for transformer families) — a block-paged KV cache
  (`kv_cache.PagedKVCache`): one shared page pool + per-slot page tables,
  page size aligned to HDP's ``block_k`` so cache pages coincide with the
  scout's pruning blocks. Decode reuses the integer scout's per-row keep
  mask to gather only surviving pages — pruned pages are never touched,
  mirroring the FUM kernel's never-DMA'd dataflow — and pages are
  allocated per request (prompt + budget), not per ``max_len`` slot.
* ``dense`` (recurrent families, and the reference A/B) — the seed
  per-slot contiguous `SlotCache`.

Admission is **batched bucketed prefill**: queued requests are grouped by
pad-bucket and stacked at exact batch size into one jitted prefill call
per group (the jit cache stays bounded by max_batch entries per bucket).
Prompts longer than the largest bucket run **chunked prefill**:
bucket-sized chunks appended at a position offset, so arbitrarily long
prompts (up to ``max_len``) prefill through the same jit entries.
Finished slots free their pages and are immediately refillable —
continuous batching.

With the **prefix cache** enabled (paged layout; ``prefix_cache=`` /
``REPRO_PREFIX_CACHE``), admission first walks a token-chunk radix tree
(`allocator.RadixPrefixCache`) for the longest cached prompt prefix:
matched full pages are *shared* into the slot's page table (refcounted by
`allocator.PageAllocator` — no copy, no recompute) and only the prompt
suffix is prefilled, through the same chunked-prefill jit at a position
offset. A full-prompt hit skips prefill entirely after copy-on-write
duplicating the one shared page the decode resume will rewrite. Finished
prompts register their full pages (strictly before the decode write
frontier) back into the tree; under pool pressure, least-recently-used
unreferenced cached pages are evicted. Shared pages are read-only by
construction *and* by enforcement: each slot's first-owned-page offset is
threaded into the decode jit as a write floor — writes below it land in
the scratch page.

The paged backend pins ``hdp.calib = "none"``: its scout copy of K is
quantized at cache-write time, so a data-dependent calibration scale
cannot be honored — the static fixed-point grid applies to prefill and
decode alike (the paper's co-processor model). Under that grid, paged
decode is token-for-token identical to the dense backend.

The decode hot path is **zero-copy and fused**: the serving cache (page
pool or slot cache) is *donated* to the decode and chunked-prefill jits
(``jax.jit(..., donate_argnums=...)``), so per-token cache updates alias
the same buffers instead of allocating a second copy of the pool every
step, and decode runs a jitted ``lax.scan`` over a configurable horizon
(``decode_horizon`` / ``REPRO_DECODE_HORIZON``) — one Python dispatch and
one host sync per H tokens with on-device EOS/budget masking, token-
identical to per-token stepping.

With **self-speculative decode** (``spec_decode`` /
``REPRO_SPEC_DECODE``; supersedes the horizon loop) each step is one
fused draft/verify round instead: ``draft_len - 1`` approximate draft
steps propose tokens by scoring attention from the int8 scout copies
alone (the always-streamed integer copy plus a write-time
quantized-fraction copy — the full-precision K pool is neither read nor
written by a draft step), then ONE ``draft_len``-wide multi-query verify
re-scores every position with full fidelity and per-query-row scout
semantics, reading the page pool once per round instead of once per
token. On-device longest-prefix acceptance commits only exact greedy
tokens (byte-identical to horizon-1 at any acceptance rate), EOS/budget
cuts mirror the horizon loop, and rejected staged writes past the new
frontier are rolled back by NaN-poisoning their K — the write floor
keeps shared prefix pages outside both staging and rollback, so the
allocator/prefix-cache invariants are untouched.

With the **stream scheduler** (``stream_sched`` / ``REPRO_STREAM_SCHED``)
the engine serves a continuous request stream instead of fixed waves:
``submit()`` enqueues into a `scheduler.StreamScheduler` waiting queue,
and every ``step()`` runs one scheduling tick before its decode —
token-budget admission against free slots *and* free-or-evictable pages,
biggest-prefix-cache-hit-first ordering, in-flight recycling of slots
vacated mid-run, and long cold prompts chunk-prefilled a slice per step
so the running batch keeps decoding underneath them. A watchdog raises
instead of spinning when nothing can ever be admitted. The streaming
``serve()`` generator yields Results in completion order, and
per-request TTFT / TPOT / queue-wait plus queue-depth aggregates land in
``summary()``. Scheduling only reorders *admission*; per-slot compute is
untouched, so outputs stay byte-identical to static-wave serving (and to
solo runs — the equivalence tests/test_serving.py pins).

HDP is active inside both prefill and decode attention when
``cfg.hdp.enabled`` — stats (block/head/page sparsity per layer) are
aggregated into engine metrics so serving examples/benchmarks can report
the achieved sparsity next to throughput. Attention implementation and
cache layout are selected by an ``repro.attention.AttnSpec``
(``attn=AttnSpec(backend="pallas")`` routes the paged HDP decode through
the block-sparse Pallas kernel, interpret mode off-TPU); the resolved
backend per phase is reported by ``summary()``. The old
``cache_backend=``/``attn_backend=`` string kwargs keep working for one
release through a deprecation shim.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.attention import (AttnSpec, DraftProfile, default_spec,
                             effective_policy, known_backend_names,
                             resolve_backend, spec_from_legacy)
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.attention import build_attn_call
from repro.serving import kv_cache
from repro.serving.allocator import PoolExhausted, RadixPrefixCache
from repro.serving.faults import FaultInjector, FaultPlan, coerce_injector
from repro.serving.scheduler import (QueueFull, SchedulerConfig,
                                     StreamScheduler)

I32 = jnp.int32

#: Families served through the block-paged transformer KV cache.
PAGEABLE_FAMILIES = ("dense", "moe", "vlm")

#: env var giving the default decode horizon (explicit kwargs win).
HORIZON_ENV = "REPRO_DECODE_HORIZON"

#: env var enabling prompt-prefix page sharing when ``prefix_cache=None``
#: is passed (explicit kwargs win; ignored for layouts that cannot share).
PREFIX_ENV = "REPRO_PREFIX_CACHE"

#: env var enabling self-speculative decode when ``spec_decode=None`` is
#: passed (explicit kwargs win; degrades silently for families that
#: cannot speculate — recurrent state has no multi-query verify).
SPEC_ENV = "REPRO_SPEC_DECODE"

#: env var giving the default draft length (explicit kwargs win).
DRAFT_ENV = "REPRO_DRAFT_LEN"

#: env var enabling the continuous-batching stream scheduler when
#: ``stream_sched=None`` is passed (explicit kwargs win).
STREAM_ENV = "REPRO_STREAM_SCHED"

#: env var enabling acceptance-adaptive speculation when
#: ``adaptive_spec=None`` is passed (explicit kwargs win; the env default
#: degrades silently when speculative decode itself is off).
ADAPTIVE_ENV = "REPRO_ADAPTIVE_SPEC"

#: env var giving the paged pool's KV storage dtype when the AttnSpec
#: leaves ``kv_dtype="auto"`` (explicit specs win; "int8" when unset —
#: the quantized pool is the production default and fp32 the opt-in
#: A/B oracle). Dense layouts always serve fp32.
KV_DTYPE_ENV = "REPRO_KV_DTYPE"

#: env var giving the default tensor-parallel degree when ``tp=None`` and
#: no mesh is passed (explicit kwargs win; the env default degrades
#: silently — to 1 — for layouts/head-counts/device-counts that cannot
#: shard, so a CI matrix can run the whole suite under it).
MESH_TP_ENV = "REPRO_MESH_TP"

#: env var giving the default engine-replica count for launch/serve.py's
#: ``--dp`` flag (the Engine itself is one replica; see serving/replica.py).
MESH_DP_ENV = "REPRO_MESH_DP"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    #: admission priority ("prefix" order mode): higher admits first, and
    #: only a strictly-lower-priority running request may be preempted to
    #: unblock a starved queue head (equal priorities never preempt).
    priority: int = 0
    #: wall-clock budget from submit() to completion; on expiry the
    #: request is cancelled with ``Result(status="deadline")`` wherever
    #: it is (queued, mid-prefill, or decoding).
    deadline_s: Optional[float] = None
    #: wall-clock budget from submit() to slot activation; expires only
    #: while still waiting (an admitted request is allowed to finish).
    max_queue_wait_s: Optional[float] = None
    # --- preempt/failover restore bookkeeping (engine-managed) ---
    #: tokens already generated before the last preempt/failover; they are
    #: folded into ``prompt`` for the recompute resume and re-emitted at
    #: the head of the final ``Result.tokens``.
    prior_tokens: Tuple[int, ...] = ()
    #: prompt length of the ORIGINAL submission (``prompt`` grows with
    #: each restore); None until the first preemption.
    orig_prompt_len: Optional[int] = None
    #: times this request was preempted or failed over so far.
    preemptions: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    prompt_len: int
    tokens: List[int]
    prefill_s: float = 0.0
    decode_steps: int = 0
    #: False when Engine.run exhausted its step budget before this request
    #: finished (tokens then hold the partial generation so far), and for
    #: every non-"ok" status.
    complete: bool = True
    #: "ok" | "cancelled" | "deadline" | "error" — the typed request
    #: outcome; non-"ok" Results carry whatever tokens were generated
    #: before the request was unwound.
    status: str = "ok"
    #: human-readable failure detail for non-"ok" statuses.
    error: Optional[str] = None
    #: times the request was preempted/failed over before finishing
    #: (its tokens are byte-identical to an uninterrupted run regardless).
    preemptions: int = 0
    #: seconds from submit() to slot activation (queue + prefill wait);
    #: None for requests served without a submit timestamp.
    queue_wait_s: Optional[float] = None
    #: seconds from submit() to the first generated token, at host-sync
    #: granularity: every token of one fused horizon/spec round shares
    #: that round's single sync timestamp.
    ttft_s: Optional[float] = None
    #: mean seconds per token after the first (same sync granularity;
    #: None when fewer than two tokens were generated).
    tpot_s: Optional[float] = None


class Engine:
    """Single-host serving engine (mesh-aware variants run via launch/serve).

    Parameters
    ----------
    cfg: ModelConfig (reduced configs run on CPU).
    params: model params; freshly initialized when None.
    max_batch: decode slot count.
    max_len: serving cache length (prompt + generation must fit).
    prefill_buckets: pad-to lengths for the prefill jit cache.
    collect_stats: aggregate HDP sparsity stats (small overhead).
    attn: AttnSpec (or a backend name/tag string) selecting both the
        attention backend (auto | reference | xla | pallas | an exact
        registry name) and the serving cache layout
        (``AttnSpec(layout=...)``: auto = paged for transformer families,
        dense otherwise). None uses the default spec (honors the
        REPRO_ATTN_BACKEND env var).
    cache_backend / attn_backend: DEPRECATED string kwargs, mapped onto
        ``attn`` via a shim for one release (emits a DeprecationWarning).
    page_size: paged-layout page length; defaults to ``hdp.block_k``
        (must match it while HDP is enabled).
    num_pages: page-pool size override (default: one full table per slot
        plus the scratch page). A larger pool gives evicted-under-
        pressure prefix pages more room to stay resident.
    prefix_cache: share prompt-prefix pages across requests through the
        refcounted radix tree (paged layout only). None reads the
        ``REPRO_PREFIX_CACHE`` env var and degrades silently when the
        layout cannot share (dense, non-rope positions, HDP chunk
        misalignment); passing True explicitly raises instead.
    decode_horizon: tokens generated per jitted decode call (the fused
        ``lax.scan`` loop) — one Python dispatch + one host sync per
        horizon instead of per token. Token-identical to horizon=1:
        EOS/budget masking runs on device, and the scan length is
        clamped per call to the longest remaining budget so the loop
        never runs steps that provably have no active slot. None reads
        ``REPRO_DECODE_HORIZON`` (default 1). Admission (slot refill)
        happens at horizon boundaries.
    spec_decode: self-speculative decode — each engine step runs ONE
        fused round of ``draft_len - 1`` approximate draft steps (the
        draft profile's cheap attention proposes tokens) plus one
        ``draft_len``-wide multi-query verify over the serving cache
        (the page pool is read once per round instead of once per
        token), with on-device longest-prefix accept, EOS/budget cuts
        and NaN-poison rollback of rejected speculative K writes.
        Exact-match acceptance makes the output token-identical to
        horizon-1 greedy decode, at any acceptance rate. Supersedes the
        ``decode_horizon`` loop when enabled. None reads
        ``REPRO_SPEC_DECODE`` and degrades silently for families whose
        cache cannot verify (recurrent state); passing True explicitly
        raises instead. Pins ``hdp.calib = "none"`` like the paged
        layout does: speculative staging leaves garbage past the commit
        frontier, which a data-dependent calibration scale would see.
    draft_len: tokens proposed+verified per speculative round (the
        verify width; committed tokens per round are 1..draft_len).
        None reads ``REPRO_DRAFT_LEN`` (default 4).
    draft_profile: DraftProfile selecting the draft pass's approximate
        attention (score source + survival-threshold overrides); None
        uses the default profile (scout-copy scores, exact-pass
        thresholds).
    adaptive_spec: acceptance-adaptive speculation — a
        `repro.autotune.SpecController` keeps a running acceptance-rate
        EMA and re-plans the draft length (1..draft_len) and the draft
        profile's prune aggressiveness before every round. Committed
        tokens stay byte-identical at any plan (exact-match acceptance
        — the knobs only move the work/acceptance tradeoff). None reads
        ``REPRO_ADAPTIVE_SPEC`` and degrades silently when spec decode
        is off; passing True explicitly without spec_decode raises.
    tuner: explicit `repro.autotune.Tuner` to install as the process
        default (shared by cost-policy dispatch everywhere; engines are
        traced against the process tuner because backend selection
        happens inside jit traces). None keeps the current default —
        created lazily, warm-started from ``REPRO_TUNER_CACHE``.
    stream_sched: continuous-batching stream scheduler —
        ``submit()`` enqueues into a waiting queue and every step runs
        one `scheduler.StreamScheduler` tick (token-budget admission,
        prefix-hit-first ordering, mid-run slot recycling, interleaved
        chunked prefill, watchdog) before decoding. Composes with every
        decode mode (horizon, prefix cache, spec decode) and never
        changes per-request tokens — only admission timing/order. None
        reads ``REPRO_STREAM_SCHED`` (default off); passing a ``sched``
        config implies True.
    sched: SchedulerConfig tuning the scheduler (chunk token budget per
        step, admission order, watchdog limits); None uses defaults.
    faults: deterministic fault injection — a `serving.faults`
        FaultInjector (share one across a ReplicaSet for fleet-wide
        once-only events), FaultPlan, or plan spec string. None reads
        ``REPRO_FAULT_PLAN`` (default: no injection). Step numbers in
        the plan count this engine's ``step()`` calls from construction.
    """

    def __init__(self, cfg: ModelConfig, params=None, *, rng=None,
                 max_batch: int = 4, max_len: int = 128,
                 prefill_buckets: Sequence[int] = (32, 64, 128),
                 collect_stats: bool = False,
                 attn: Optional[AttnSpec] = None,
                 cache_backend: Optional[str] = None,
                 attn_backend: Optional[str] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 prefix_cache: Optional[bool] = None,
                 decode_horizon: Optional[int] = None,
                 spec_decode: Optional[bool] = None,
                 draft_len: Optional[int] = None,
                 draft_profile: Optional[DraftProfile] = None,
                 adaptive_spec: Optional[bool] = None,
                 tuner=None,
                 stream_sched: Optional[bool] = None,
                 sched: Optional[SchedulerConfig] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 tp: Optional[int] = None,
                 faults: Union[FaultInjector, FaultPlan, str, None] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "enc-dec serving uses launch/serve.py --arch whisper path")
        if isinstance(attn, str):
            attn = AttnSpec(backend=attn)
        spec = attn if attn is not None else default_spec()
        if attn_backend is not None or cache_backend is not None:
            spec = spec_from_legacy(attn_backend, cache_backend, base=spec)
        for phase in ("prefill", "decode"):
            req = spec.requested_for(phase)
            if req != "auto" and req not in known_backend_names():
                raise ValueError(
                    f"unknown attention backend {req!r} ({phase}); "
                    f"known: {known_backend_names()}")
        layout = spec.layout
        if layout == "auto":
            layout = ("paged" if cfg.family in PAGEABLE_FAMILIES else "dense")
        if layout == "paged" and cfg.family not in PAGEABLE_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} has no KV pages; use dense layout")
        kv_dtype = spec.kv_dtype
        if kv_dtype == "auto":
            kv_dtype = os.environ.get(KV_DTYPE_ENV, "") or "int8"
            if kv_dtype not in kv_cache.KV_DTYPES:
                raise ValueError(
                    f"{KV_DTYPE_ENV}={kv_dtype!r}: must be one of "
                    f"{kv_cache.KV_DTYPES}")
        if layout != "paged":
            kv_dtype = "fp32"     # dense slot caches have no quantized store
        # pin the resolved dtype back into the spec: attn_apply keys its
        # prefill round-trip (and nothing else) off attn.kv_dtype, so the
        # spec the jits close over must carry the concrete value
        spec = spec.replace(kv_dtype=kv_dtype)
        self.kv_dtype = kv_dtype
        if (layout == "paged" and cfg.hdp is not None
                and cfg.hdp.enabled and cfg.hdp.calib != "none"):
            # write-time scout quantization cannot honor a data-dependent
            # calibration scale; pin the static grid for prefill + decode
            # alike so the engine stays self-consistent (and identical to
            # the dense backend under the same effective config)
            cfg = cfg.replace(hdp=cfg.hdp.replace(calib="none"))
        spec_capable = cfg.family in PAGEABLE_FAMILIES
        if spec_decode is None:
            env = os.environ.get(SPEC_ENV, "")
            spec_decode = env.lower() in ("1", "true", "on") if env else False
            spec_decode = spec_decode and spec_capable   # env default degrades
        elif spec_decode and not spec_capable:
            raise ValueError(
                f"spec_decode=True: family {cfg.family!r} has no multi-query "
                "verify path (recurrent state cannot re-score draft "
                "positions against a cache)")
        self.spec = bool(spec_decode)
        if draft_len is None:
            draft_len = int(os.environ.get(DRAFT_ENV, "4") or 4)
        if draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {draft_len}")
        self.draft_len = int(draft_len)
        self.draft_profile = draft_profile if draft_profile is not None \
            else DraftProfile()
        if adaptive_spec is None:
            env = os.environ.get(ADAPTIVE_ENV, "")
            adaptive_spec = env.lower() in ("1", "true", "on") if env else False
            adaptive_spec = adaptive_spec and self.spec   # env default degrades
        elif adaptive_spec and not self.spec:
            raise ValueError(
                "adaptive_spec=True requires spec_decode (there is no "
                "draft length to adapt without speculative rounds)")
        self.spec_ctl = None
        if adaptive_spec:
            from repro.autotune import SpecConfig, SpecController
            self.spec_ctl = SpecController(
                self.draft_profile,
                cfg.hdp if cfg.hdp is not None and cfg.hdp.enabled else None,
                SpecConfig(k_max=self.draft_len))
        if (self.spec and layout != "paged" and cfg.hdp is not None
                and cfg.hdp.enabled and cfg.hdp.calib != "none"):
            # the paged pinning above, for the same reason seen from the
            # other side: rejected speculative writes leave garbage (or
            # rollback poison) past the commit frontier, which a
            # data-dependent calibration scale computed over the cache
            # extent would observe — breaking token identity with the
            # non-speculative baseline
            cfg = cfg.replace(hdp=cfg.hdp.replace(calib="none"))
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.buckets = sorted(b for b in prefill_buckets if b <= max_len) \
            or [max_len]
        self.collect_stats = collect_stats
        self.paged = layout == "paged"
        self.attn_spec = spec
        self.policy = effective_policy(spec)
        self.tuner = None
        if tuner is not None:
            # backend selection happens inside jit traces, which consult
            # the process-default tuner — install the explicit one there
            from repro.autotune import set_default_tuner
            set_default_tuner(tuner)
        if self.policy == "cost":
            from repro.autotune import default_tuner
            self.tuner = default_tuner()
        # static retrace token for the decode/spec AND prefill/chunk jits:
        # bumped when a flushed probe flips a tuner decision, so exactly
        # the affected programs re-trace (and re-consult the tuner).
        self._attn_epoch = 0
        if decode_horizon is None:
            decode_horizon = int(os.environ.get(HORIZON_ENV, "1") or 1)
        if decode_horizon < 1:
            raise ValueError(f"decode_horizon must be >= 1, got {decode_horizon}")
        self.horizon = int(decode_horizon)

        # ---- serving mesh (tensor-parallel paged attention) ----
        if spec.kv_scale == "absmax" and kv_dtype == "fp32":
            raise ValueError(
                "kv_scale='absmax' calibrates a quantized pool's scales; "
                "it needs kv_dtype='int8'/'fp8_v' and the paged layout")
        self.kv_scale = spec.kv_scale if layout == "paged" else "grid"
        if mesh is not None:
            mesh_tp = int(dict(mesh.shape).get("model", 1))
            if tp is not None and int(tp) != mesh_tp:
                raise ValueError(
                    f"tp={tp} disagrees with the mesh's model axis "
                    f"({mesh_tp})")
            tp = mesh_tp
        if tp is None:
            env = os.environ.get(MESH_TP_ENV, "")
            try:
                tp = int(env) if env else 1
            except ValueError:
                raise ValueError(f"{MESH_TP_ENV}={env!r}: not an int")
            # env default degrades silently, like the other REPRO_* envs,
            # so a CI leg can run every engine under it
            if (layout != "paged" or tp < 1 or cfg.n_kv_heads % max(tp, 1)
                    or len(jax.devices()) < tp):
                tp = 1
        tp = int(tp)
        if tp > 1:
            if layout != "paged":
                raise ValueError(
                    "tp > 1 shards the paged page pool along the head "
                    "axis; dense-layout families cannot serve sharded")
            if cfg.n_kv_heads % tp != 0:
                raise ValueError(
                    f"n_kv_heads={cfg.n_kv_heads} not divisible by tp={tp}")
            if mesh is None:
                from repro.launch.mesh import make_serving_mesh
                mesh = make_serving_mesh(tp=tp)
            self.mesh, self.tp = mesh, tp
        else:
            self.mesh, self.tp = None, 1

        if params is None:
            rng = rng if rng is not None else jax.random.PRNGKey(0)
            params, _ = registry.init_params(cfg, rng)
        self.params = params

        if self.paged:
            self.pages = kv_cache.PagedKVCache(
                cfg, max_batch, max_len, page_size=page_size,
                num_pages=num_pages,
                # the draft's scores come from the int8 scout copies; the
                # quantized-fraction copy is only worth pool memory when a
                # *fp32* pool speculates with scout-copy scores (quantized
                # pools derive both scout views from the codes for free)
                draft_scout=self.spec and self.draft_profile.scores == "scout",
                kv_dtype=kv_dtype, kv_scale=self.kv_scale, mesh=self.mesh)
        else:
            # speculative rounds stage writes up to draft_len - 1 positions
            # past the commit frontier before rolling back; the dense slot
            # cache carries that margin so staged writes near max_len can
            # never clamp onto (and corrupt) committed positions. The
            # positions are causally invisible until rewritten, exactly
            # like bucket padding. (The paged layout needs no margin: its
            # write path scratch-redirects past-the-table columns.)
            margin = self.draft_len - 1 if self.spec else 0
            self.slots = kv_cache.SlotCache(cfg, max_batch, max_len + margin)
        self.prefix = self._build_prefix_cache(prefix_cache)
        self._free = list(range(max_batch))
        self._active: Dict[int, Dict[str, Any]] = {}  # slot -> request state
        self._results: Dict[int, Result] = {}
        self._queue: List[Request] = []
        self._last_tok = jnp.zeros((max_batch, 1), I32)
        self._pos = jnp.zeros((max_batch,), I32)
        # device-resident per-slot decode state: written at install time,
        # refreshed from the fused loop's own carry after every horizon —
        # the steady-state decode step uploads no host arrays at all
        self._active_dev = jnp.zeros((max_batch,), bool)
        self._remaining_dev = jnp.zeros((max_batch,), I32)
        self._eos_dev = jnp.full((max_batch,), -1, I32)
        # per-slot first-owned-page offset: table entries below it are
        # shared read-only prefix pages; the decode write path redirects
        # anything below the floor to the scratch page
        self._floor_dev = jnp.zeros((max_batch,), I32)
        self.metrics: Dict[str, float] = self._fresh_metrics()
        #: submit() timestamps per uid (popped at finish) and the finish
        #: order log the streaming serve() generator drains
        self._t_submit: Dict[int, float] = {}
        self._finished: List[int] = []
        #: uid -> (absolute deadline, absolute queue-wait deadline),
        #: enforced at the top of every step; popped at finish
        self._deadlines: Dict[int, Tuple[Optional[float],
                                         Optional[float]]] = {}
        #: activation sequence counter — the preemption victim tiebreak
        #: (newest activation preempts first: it has the least sunk work)
        self._act_seq = 0
        #: engine step counter driving the fault plan's step schedule
        self._cur_step = 0
        self.faults = coerce_injector(faults)
        #: cached all-false logit-poison mask for fault-free steps (the
        #: jitted decode/verify always takes the mask so injection never
        #: changes the compiled program)
        self._zero_inject = jnp.zeros((max_batch,), bool)
        if stream_sched is None:
            env = os.environ.get(STREAM_ENV, "")
            stream_sched = env.lower() in ("1", "true", "on") if env \
                else sched is not None
        self.sched = StreamScheduler(self, sched or SchedulerConfig()) \
            if stream_sched else None

        # buffer donation: the serving cache (page pool / slot cache) is
        # aliased in place by the batched-prefill, chunked-prefill and
        # decode jits instead of copied per call; take()/put() on the
        # cache objects keep stale host handles from being reused after a
        # donating call. Batched prefill fuses the prompt forward with the
        # page/slot scatter in one donated jit, so no undonated O(pool)
        # insert copy remains on the admission path.
        self._prefill_jit = jax.jit(
            self._prefill_paged_fn if self.paged else self._prefill_dense_fn,
            static_argnums=(2, 3), donate_argnums=(4,))
        self._chunk_jit = jax.jit(self._prefill_chunk_fn,
                                  static_argnums=(2,), donate_argnums=(3,))
        # static argnums: scan length / draft plan + the attention epoch
        # (cost-policy retrace token); the spec round also threads the
        # round's DraftProfile statically so the adaptive controller can
        # swap profiles at a bounded number of compile entries
        self._decode_jit = jax.jit(
            self._decode_loop_paged_fn if self.paged
            else self._decode_loop_dense_fn,
            static_argnums=(0, 1), donate_argnums=(4,))
        self._spec_jit = jax.jit(
            self._spec_round_paged_fn if self.paged
            else self._spec_round_dense_fn,
            static_argnums=(0, 1, 2), donate_argnums=(5,))

    # ------------------------------------------------------------ serving mesh
    def _mesh_ctx(self):
        """Ambient-mesh context every jitted step runs under: at trace
        time the model layer consults it to route paged-decode attention
        through the head-sharded shard_map wrapper (a no-op context when
        the engine is unsharded)."""
        from repro.distribution.tp import serving_mesh
        return serving_mesh(self.mesh)

    # ------------------------------------------------------------ prefix cache
    def _build_prefix_cache(self, requested) -> Optional[RadixPrefixCache]:
        capable = self.paged and self._can_chunk
        if requested is None:
            env = os.environ.get(PREFIX_ENV, "")
            requested = env.lower() in ("1", "true", "on") if env else False
            requested = requested and capable   # env default degrades
        if not requested:
            return None
        if not self.paged:
            raise ValueError(
                "prefix_cache=True requires the paged cache layout "
                "(AttnSpec(layout='paged'))")
        if not self._can_chunk:
            raise ValueError(
                "prefix_cache=True needs offset-capable prefill (rope "
                "positions, HDP chunk boundaries on block_q) — this config "
                "cannot prefill a prompt suffix in isolation")
        return RadixPrefixCache(self.pages.allocator, self.pages.page_size)

    @property
    def _page_align(self) -> int:
        """Pages per shareable unit: a match boundary must sit on an HDP
        q-block boundary or the suffix scout would pool across it."""
        hdp = self.cfg.hdp
        if hdp is not None and hdp.enabled:
            return math.lcm(self.pages.page_size, hdp.block_q) \
                // self.pages.page_size
        return 1

    # ------------------------------------------------------------ jitted fns
    def _prefill_body(self, params, tokens, bucket_len):
        cache = registry.init_cache(self.cfg, tokens.shape[0],
                                    max_len=bucket_len)
        batch = {"tokens": tokens}
        _, new_cache, stats = registry.apply_prefill(
            self.cfg, params, batch, cache,
            collect_stats=self.collect_stats, attn=self.attn_spec)
        return new_cache, stats

    def _prefill_paged_fn(self, params, tokens, bucket_len, epoch, pool,
                          page_idx):
        """Batched prefill fused with the page scatter, pool donated.

        ``page_idx`` [nb, pages_per_slot]: destination pool page per
        request-cache page (0-padded — the scratch page absorbs bucket
        padding, exactly as in `PagedKVCache.insert`)."""
        del epoch  # static retrace token only — selection reruns per trace
        one_cache, stats = self._prefill_body(params, tokens, bucket_len)
        for r in range(tokens.shape[0]):
            pool = self.pages._insert_fn(pool, one_cache["k"],
                                         one_cache["v"], page_idx[r], r)
        return pool, stats

    def _prefill_dense_fn(self, params, tokens, bucket_len, epoch,
                          slot_cache, slots):
        """Batched prefill fused with the slot insert, slot cache donated."""
        del epoch
        one_cache, stats = self._prefill_body(params, tokens, bucket_len)
        for r in range(tokens.shape[0]):
            slot_cache = kv_cache.insert_slot(slot_cache, one_cache,
                                              slots[r], self.slots.axes,
                                              row=r)
        return slot_cache, stats

    def _prefill_chunk_fn(self, params, tokens, epoch, cache, offset):
        del epoch  # static retrace token only
        _, new_cache, stats = registry.apply_prefill(
            self.cfg, params, {"tokens": tokens}, cache,
            collect_stats=self.collect_stats, pos_offset=offset,
            attn=self.attn_spec)
        return new_cache, stats

    def _decode_step(self, params, token, cache, pos, table, floors=None,
                     inject=None):
        if table is not None:
            logits, new_cache, stats = registry.apply_decode(
                self.cfg, params, token, cache, pos[:, None],
                collect_stats=self.collect_stats, page_table=table,
                write_floor=floors, attn=self.attn_spec)
        else:
            logits, new_cache, stats = registry.apply_decode(
                self.cfg, params, token, cache, pos[:, None],
                collect_stats=self.collect_stats, attn=self.attn_spec)
        if inject is not None:
            # fault harness: poison the selected rows' logits so the
            # tripwire below fires exactly as it would for organic NaNs
            logits = jnp.where(inject[:, None, None], jnp.nan, logits)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(I32)[:, None]
        # per-slot tripwire: a non-finite logit row means this request's
        # state is poisoned (overflow, stale staging read, bad page) —
        # flag it so the host can abort ONLY that request while the rest
        # of the batch keeps its token-identical stream
        bad = ~jnp.isfinite(logits[:, -1]).all(axis=-1)
        return nxt, bad, new_cache, stats

    def _decode_loop(self, length, params, tok, cache, table, floors, pos,
                     active, remaining, eos, inject):
        """``length`` fused decode steps as one jitted lax.scan.

        On-device bookkeeping mirrors the host loop exactly: a slot is
        done when its budget runs out (``remaining``) or it emits its
        ``eos`` id (-1 = none); done slots park on token 0 / position 0
        with their page-table row zeroed, so their writes land in the
        scratch page. A slot whose logits go non-finite (the per-slot
        tripwire; ``inject`` forces it for the fault harness) parks the
        same way but is reported faulted instead of emitting its token.
        Emitted per step: (token [B], pre-step active mask [B], fault
        mask [B], stats) — the active mask tells the host which emitted
        tokens are real, keeping horizon-H output token-identical to H=1
        even when EOS fires mid-horizon. ``length`` is static (the host
        clamps it to the longest remaining budget, so the scan never
        runs steps that provably have no active slot; at most
        ``horizon`` distinct compile entries exist per engine).
        """
        def body(carry, _):
            tok, cache, pos, active, remaining = carry
            table_eff = (None if table is None
                         else jnp.where(active[:, None], table, 0))
            nxt, bad, cache2, stats = self._decode_step(
                params, tok, cache, pos, table_eff, floors, inject)
            fault = active & bad
            done = active & ~fault & ((remaining <= 1)
                                      | ((eos >= 0) & (nxt[:, 0] == eos)))
            gone = done | fault
            carry = (jnp.where(gone[:, None], 0, nxt), cache2,
                     jnp.where(gone, 0, pos + 1), active & ~gone,
                     remaining - active.astype(I32))
            return carry, (nxt[:, 0], active, fault, stats)

        carry, ys = jax.lax.scan(body, (tok, cache, pos, active, remaining),
                                 None, length=length)
        tok, cache, pos, active, remaining = carry
        return ys, tok, cache, pos, active, remaining

    def _decode_loop_paged_fn(self, length, epoch, params, tok, cache, table,
                              floors, pos, active, remaining, eos, inject):
        del epoch  # static retrace token only — selection reruns per trace
        return self._decode_loop(length, params, tok, cache, table, floors,
                                 pos, active, remaining, eos, inject)

    def _decode_loop_dense_fn(self, length, epoch, params, tok, cache, pos,
                              active, remaining, eos, inject):
        del epoch
        return self._decode_loop(length, params, tok, cache, None, None,
                                 pos, active, remaining, eos, inject)

    # ------------------------------------------------------ speculative round
    def _draft_step(self, params, token, cache, pos, table, floors,
                    profile):
        """One approximate draft decode step (cheap attention per the
        round's DraftProfile; never collects stats)."""
        kw = {"page_table": table, "write_floor": floors} \
            if table is not None else {}
        logits, new_cache, _ = registry.apply_decode(
            self.cfg, params, token, cache, pos[:, None],
            collect_stats=False, attn=self.attn_spec,
            draft=profile, **kw)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(I32)[:, None]
        return nxt, new_cache

    def _verify_step(self, params, tokens, cache, pos_rows, table, floors,
                     inject=None):
        """One k-wide multi-query verify: all k positions re-scored (and
        their exact K/V re-written, overwriting the draft's staging) in a
        single batched attention call over the serving cache. Rows with
        any non-finite logit (or forced by ``inject``) are reported
        faulted — the round commits nothing for them."""
        kw = {"page_table": table, "write_floor": floors} \
            if table is not None else {}
        logits, new_cache, stats = registry.apply_decode(
            self.cfg, params, tokens, cache, pos_rows,
            collect_stats=self.collect_stats, attn=self.attn_spec, **kw)
        if inject is not None:
            logits = jnp.where(inject[:, None, None], jnp.nan, logits)
        bad = ~jnp.isfinite(logits).all(axis=(1, 2))
        return jnp.argmax(logits, axis=-1).astype(I32), bad, new_cache, stats

    def _poison_rejected(self, cache, table_eff, floors, pos, n_commit,
                         active, k):
        """Rollback fence: NaN-poison the K of rejected speculative writes.

        Positions ``pos + n_commit .. pos + k - 1`` hold K/V of tokens
        the verify refuted; by construction they are rewritten before any
        masked read can see them, and this poison makes that invariant
        self-enforcing — a stale read would surface as NaN in the logits
        instead of a silent wrong token. K-only, like the allocator's
        freed-page poison: masked V reads still multiply by exact zeros.
        Sub-floor (shared, read-only) pages are never poisoned — the
        write fences are the SAME the K/V scatter honors
        (models.attention.resolve_write_pages).

        The gather-then-writeback shape is load-bearing, not a missed
        optimization: non-rejected lanes must NOT be redirected into the
        scratch page, because scratch K is subject to the pool-wide
        arbitrary-but-FINITE contract — an early-head-gated head's pages
        are never fetched (gathers read scratch in their place) while
        its softmax still runs before the gate zeroes the output, so
        NaN in scratch K becomes NaN * 0 = NaN in the head gate and
        poisons every downstream activation.

        Quantized pools have no NaN to write — the reserved int8 code
        -128 is the position-granular sentinel instead: stage 3 decodes
        it to NaN (the same tripwire), while the derived scout views map
        it to 0 (finite scores, exactly like the fp32 pools' separate
        finite scout copies)."""
        from repro.core.quant import POISON_CODE
        from repro.models.attention import resolve_write_pages
        steps = jnp.arange(k, dtype=I32)
        stale = pos[:, None] + steps[None]                  # [B, k]
        reject = active[:, None] & (steps[None] >= n_commit[:, None])
        if self.paged:
            ps = self.pages.page_size
            ent = resolve_write_pages(stale, table_eff, ps, floors)
            reject = reject & (ent != 0)     # never poison the scratch page
            off = stale % ps
            kp = cache["k_pages"]                           # [L, P, ps, N, hd]
            poison = (jnp.asarray(POISON_CODE, kp.dtype)
                      if kp.dtype == jnp.int8
                      else jnp.asarray(jnp.nan, kp.dtype))
            cur = kp[:, ent, off]                           # [L, B, k, N, hd]
            val = jnp.where(reject[None, :, :, None, None], poison, cur)
            return {**cache, "k_pages": kp.at[:, ent, off].set(val)}
        kc = cache["k"]                                     # [L, B, S, N, hd]
        b = jnp.arange(kc.shape[1])[:, None]
        cur = kc[:, b, stale]                               # [L, B, k, N, hd]
        val = jnp.where(reject[None, :, :, None, None],
                        jnp.asarray(jnp.nan, cur.dtype), cur)
        return {**cache, "k": kc.at[:, b, stale].set(val)}

    def _spec_round(self, k, profile, params, tok, cache, table, floors,
                    pos, active, remaining, eos, inject):
        """One fused self-speculative round (``k`` = draft_len, static).

        Draft: ``k - 1`` sequential decode steps under the draft profile
        propose d_1..d_{k-1} (staged K/V writes ride the normal write
        path, floor-fenced). Verify: ONE ``k``-wide multi-query decode
        over [last_committed, d_1..d_{k-1}] re-scores every position with
        full fidelity — its exact K/V writes overwrite the draft staging
        — and yields the exact greedy token e_j per row. Accept: commit
        e_1..e_m where m-1 is the longest prefix with d_j == e_j; every
        committed token is an *exact* greedy token, so the output is
        token-identical to non-speculative decode at any acceptance rate.
        EOS and budget cut commits exactly like the fused horizon loop;
        rejected staged writes past the new frontier are NaN-poisoned.

        Emits (exact tokens [k, B], commit mask [k, B], fault mask [B],
        verify stats) + the updated carry — one host sync per round. A
        faulted row (non-finite verify logits, organic or injected)
        commits nothing, is parked like a done slot, and its staged
        writes are fully poisoned by the rollback fence (n_commit = 0).
        """
        table_eff = (None if table is None
                     else jnp.where(active[:, None], table, 0))

        if k > 1:
            def body(carry, _):
                tok_i, cache_i, pos_i = carry
                nxt, cache_i = self._draft_step(params, tok_i, cache_i,
                                                pos_i, table_eff, floors,
                                                profile)
                return (nxt, cache_i, pos_i + 1), nxt[:, 0]

            (_, cache, _), ds = jax.lax.scan(
                body, (tok, cache, pos), None, length=k - 1)
            drafts = jnp.moveaxis(ds, 0, 1)                 # [B, k-1]
        else:
            drafts = jnp.zeros((tok.shape[0], 0), I32)

        ver_in = jnp.concatenate([tok, drafts], axis=1)     # [B, k]
        steps = jnp.arange(k, dtype=I32)
        ver_pos = pos[:, None] + steps[None]                # [B, k]
        exact, bad, cache, stats = self._verify_step(
            params, ver_in, cache, ver_pos, table_eff, floors, inject)
        fault = active & bad

        # longest accepted prefix: drafts[:, j] proposed the token the
        # verify re-derived as exact[:, j]; the first mismatch still
        # commits the exact token (the "free" correction)
        lead = jnp.cumprod((drafts == exact[:, :k - 1]).astype(I32), axis=1)
        n_best = 1 + lead.sum(axis=1)                       # [B] in [1, k]
        within = steps[None] < n_best[:, None]
        is_eos = (eos[:, None] >= 0) & (exact == eos[:, None])
        cut = (is_eos & within).astype(I32)
        eos_before = jnp.cumsum(cut, axis=1) - cut          # EOS strictly before
        commit = (within & (eos_before == 0)
                  & (steps[None] < remaining[:, None]) & active[:, None]
                  & ~fault[:, None])
        n_commit = commit.sum(axis=1).astype(I32)

        cache = self._poison_rejected(cache, table_eff, floors, pos,
                                      n_commit, active, k)
        eos_hit = (is_eos & commit).any(axis=1)
        remaining = remaining - n_commit
        done = active & ~fault & (eos_hit | (remaining <= 0))
        new_active = active & ~done & ~fault
        last = jnp.take_along_axis(
            exact, jnp.maximum(n_commit - 1, 0)[:, None], axis=1)
        tok = jnp.where(new_active[:, None], last, 0)
        pos = jnp.where(new_active, pos + n_commit, 0)
        return ((exact.T, commit.T, fault, stats), tok, cache, pos,
                new_active, remaining)

    def _spec_round_paged_fn(self, k, profile, epoch, params, tok, cache,
                             table, floors, pos, active, remaining, eos,
                             inject):
        del epoch  # static retrace token only
        return self._spec_round(k, profile, params, tok, cache, table,
                                floors, pos, active, remaining, eos, inject)

    def _spec_round_dense_fn(self, k, profile, epoch, params, tok, cache,
                             pos, active, remaining, eos, inject):
        del epoch
        return self._spec_round(k, profile, params, tok, cache, None, None,
                                pos, active, remaining, eos, inject)

    # --------------------------------------------------------------- public
    def submit(self, req: Request, *, deadline_s: Optional[float] = None,
               max_queue_wait_s: Optional[float] = None) -> None:
        """Enqueue a request.

        ``deadline_s`` / ``max_queue_wait_s`` override the request's own
        fields (convenience for callers that build Requests elsewhere).
        Raises `QueueFull` when the stream scheduler's waiting queue is
        at ``SchedulerConfig.max_queue_depth`` — typed backpressure; the
        request is NOT enqueued and no Result is recorded for it."""
        if deadline_s is not None:
            req = dataclasses.replace(req, deadline_s=deadline_s)
        if max_queue_wait_s is not None:
            req = dataclasses.replace(req, max_queue_wait_s=max_queue_wait_s)
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt+generation exceeds max_len")
        if self.sched is not None:
            depth_max = self.sched.cfg.max_queue_depth
            if depth_max is not None and self.sched.depth >= depth_max:
                self.metrics["queue_rejected"] += 1
                raise QueueFull(
                    f"request {req.uid}: waiting queue at "
                    f"max_queue_depth={depth_max}; back off and resubmit")
        now = time.perf_counter()
        self._t_submit[req.uid] = now
        if req.deadline_s is not None or req.max_queue_wait_s is not None:
            self._deadlines[req.uid] = (
                now + req.deadline_s if req.deadline_s is not None else None,
                now + req.max_queue_wait_s
                if req.max_queue_wait_s is not None else None)
        if self.sched is not None:
            self.sched.enqueue(req)
        else:
            self._queue.append(req)

    def _bucket_for(self, n: int) -> int:
        if self.cfg.family in ("rwkv6", "zamba2"):
            # recurrent state: prefilling pad tokens would corrupt the
            # SSM state, so these families prefill at exact length
            return n
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_len

    @property
    def _can_chunk(self) -> bool:
        # chunked prefill needs an absolute-position embedding that can be
        # applied per chunk (rope) and a seq-indexed cache; with HDP active
        # the chunk boundary must also sit on a q-block boundary, or the
        # scout's per-block-row pooling shifts relative to one-shot prefill
        if self.cfg.family not in PAGEABLE_FAMILIES \
                or self.cfg.pos_emb != "rope":
            return False
        hdp = self.cfg.hdp
        if hdp is not None and hdp.enabled \
                and self.buckets[-1] % hdp.block_q:
            return False  # falls back to one-shot prefill at max_len
        return True

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        n = min(len(self._queue), len(self._free))
        if n == 0:
            return
        take = [self._queue.pop(0) for _ in range(n)]
        groups: Dict[int, List[Request]] = {}
        long_reqs: List[Request] = []
        hits: List = []
        for req in take:
            plen = len(req.prompt)
            if self._can_chunk and plen > self.buckets[-1]:
                # long prompts prefill one at a time — defer their prefix
                # match so they can hit pages registered by *this* wave's
                # earlier requests (the shared-prompt burst case)
                long_reqs.append(req)
                continue
            shared = self._prefix_match(req) if self.prefix is not None \
                else None
            if shared:
                hits.append((req, shared))
            else:
                groups.setdefault(self._bucket_for(plen), []).append(req)
        jobs = []
        for bucket in sorted(groups):
            reqs = groups[bucket]
            for i in range(0, len(reqs), self.max_batch):
                jobs.append((bucket, reqs[i:i + self.max_batch]))
        # every work item is popped BEFORE it runs: a failing item unwinds
        # itself (requeue + ref release), the except arm below unwinds
        # only the never-started remainder — nothing is dropped, no match
        # ref is released twice
        try:
            while jobs:
                bucket, chunk = jobs.pop(0)
                self._prefill_group(bucket, chunk)
            while hits:
                req, shared = hits.pop(0)
                self._serve_hit(req, shared)
            while long_reqs:
                req = long_reqs.pop(0)
                shared = self._prefix_match(req) if self.prefix is not None \
                    else None
                if shared:
                    self._serve_hit(req, shared)
                else:
                    self._serve_cold(req)
        except BaseException:
            for _, chunk in jobs:
                self._queue[:0] = chunk
            for req, shared in hits:
                self.pages.allocator.unref(shared)
                self._queue.append(req)
            self._queue.extend(long_reqs)
            raise

    def _serve_hit(self, req: Request, shared: List[int]) -> None:
        """Serve a prefix-cache hit, unwinding cleanly on failure.

        Page reservation (the realistic failure: pool exhausted) happens
        up front. A reservation failure falls back to *cold* serving:
        the hit's own match refs can pin every evictable cached page, so
        releasing them and prefilling from scratch (which may now evict
        them) can succeed where the hit cannot — sharing is an
        optimization, never a reason to fail a request the cold path
        could serve. Any later pre-assignment failure releases the match
        refs and the reserved pages and requeues the request; once the
        slot owns the pages (``assigned``), slot teardown covers them."""
        full = len(shared) * self.pages.page_size == len(req.prompt)
        need = self._pages_for(req) - len(shared) + (1 if full else 0)
        try:
            fresh = self._reserve(need)
        except PoolExhausted:
            self.pages.allocator.unref(shared)
            self._serve_cold(req)
            return
        except BaseException:
            self.pages.allocator.unref(shared)
            self._queue.append(req)
            raise
        slot = self._free.pop(0)
        assigned = []
        try:
            if full:
                self._install_hit(req, shared, fresh, slot, assigned)
            else:
                self._prefill_suffix(req, shared, fresh, slot, assigned)
        except BaseException:
            if not assigned:
                self.pages.allocator.unref(shared + fresh)
                self._free.insert(0, slot)
                self._queue.append(req)
            elif req.uid not in self._results:
                # assigned but never activated: tear the slot down so
                # neither it nor its pages leak outside _active's reach
                self.pages.free(slot)
                self._free.insert(0, slot)
                self._queue.append(req)
            raise

    def _serve_cold(self, req: Request) -> None:
        """Prefill a request from scratch (no page sharing)."""
        plen = len(req.prompt)
        if self._can_chunk and plen > self.buckets[-1]:
            try:
                self._prefill_long(req)
            except BaseException:
                self._queue.append(req)
                raise
        else:
            self._prefill_group(self._bucket_for(plen), [req])

    def _prefix_match(self, req: Request) -> Optional[List[int]]:
        """Longest usable cached prefix of the prompt, as ref'd pages
        (page-granular, trimmed to HDP q-block alignment in the tree)."""
        return self.prefix.match(req.prompt, align=self._page_align) or None

    def _reserve(self, need: int) -> List[int]:
        """Allocate fresh pages, evicting LRU cached prefixes on pressure."""
        if self.faults is not None \
                and self.faults.pool_exhausted(self._cur_step):
            self.metrics["faults_injected"] += 1
            raise PoolExhausted(
                f"injected pool exhaustion (engine step {self._cur_step})")
        short = need - self.pages.allocator.available
        if short > 0 and self.prefix is not None:
            self.prefix.evict(short)
        return self.pages.allocator.alloc(need)

    def _pages_for(self, req: Request) -> int:
        return max(1, -(-(len(req.prompt) + req.max_new_tokens)
                        // self.pages.page_size))

    def _register_prefix(self, req: Request, slot: int) -> None:
        """Cache the slot's full prompt pages for future prefix hits.

        Only pages strictly before the decode write frontier (the resume
        rewrite at ``plen - 1``) are registered — a registered page is
        immutable from this moment on."""
        n_reg = (len(req.prompt) - 1) // self.pages.page_size
        if n_reg > 0:
            self.prefix.insert(req.prompt[:n_reg * self.pages.page_size],
                               self.pages.slot_pages(slot)[:n_reg])

    def _prefill_group(self, bucket: int, reqs: List[Request]) -> None:
        """One jitted prefill over same-bucket requests, stacked, fused
        with the cache scatter (the pool / slot cache is donated to it).

        The batch is stacked at exact size: the jit cache stays bounded by
        max_batch entries per bucket, and no duplicated padding row skews
        the recorded HDP stats."""
        nb = len(reqs)
        toks = np.zeros((nb, bucket), np.int32)
        for r, req in enumerate(reqs):
            plen = len(req.prompt)
            toks[r, :plen] = np.asarray(req.prompt, np.int32)
            # right-pad with the last token (positions beyond plen are
            # causally invisible to real rows and overwritten during
            # decode before they are ever attended)
            toks[r, plen:] = toks[r, plen - 1]
        slots = [self._free.pop(0) for _ in reqs]
        if self.paged:
            page_idx = np.zeros((nb, self.pages.pages_per_slot), np.int32)
            try:
                for r, (req, slot) in enumerate(zip(reqs, slots)):
                    pages = self._reserve(self._pages_for(req))
                    self.pages.assign(slot, pages)
                    page_idx[r, :len(pages)] = pages
            except BaseException:
                # pool exhausted mid-group: release what was assigned and
                # put slots + requests back — nothing leaks, nothing drops
                for slot in slots:
                    self.pages.free(slot)
                self._free[:0] = slots
                self._queue[:0] = reqs
                raise
            store, scatter = self.pages, jnp.asarray(page_idx)
        else:
            store, scatter = self.slots, jnp.asarray(slots, I32)
        t0 = time.perf_counter()
        cache = store.take()                       # donated to the jit below
        try:
            with self._mesh_ctx():
                new_cache, stats = self._prefill_jit(
                    self.params, jnp.asarray(toks), bucket, self._attn_epoch,
                    cache, scatter)
        except BaseException:
            store.restore_if_undonated(cache)
            for slot in slots:                     # roll admission back
                if self.paged:
                    self.pages.free(slot)
            self._free[:0] = slots
            self._queue[:0] = reqs
            raise
        store.put(new_cache)
        self._record_stats(stats)
        dt = time.perf_counter() - t0
        self.metrics["prefill_s"] += dt
        self.metrics["prefill_calls"] += 1
        # padded forward size — the prefill-FLOPs proxy the prefix-cache
        # A/B asserts on (wall time is too load-sensitive for CI)
        self.metrics["prefill_tokens"] += nb * bucket
        for r, (req, slot) in enumerate(zip(reqs, slots)):
            self._activate(req, slot, dt / nb)
            if self.prefix is not None:
                self._register_prefix(req, slot)

    def _tail_len(self, rem: int, off: int) -> int:
        for b in self.buckets:
            if b >= rem and off + b <= self.max_len:
                return b
        return rem  # exact-length fallback (one compile per distinct rem)

    def _chunk_step(self, prompt: np.ndarray, cache, off: int):
        """One `_chunk_jit` call at position ``off``; returns the updated
        (cache, off). The unit the stream scheduler's interleaved prefill
        advances by — decode runs between consecutive calls there."""
        plen = len(prompt)
        chunk = self.buckets[-1]
        rem = plen - off
        clen = chunk if rem >= chunk else self._tail_len(rem, off)
        piece = np.full((1, clen), prompt[plen - 1], np.int32)
        piece[0, :min(rem, clen)] = prompt[off:off + clen]
        with self._mesh_ctx():
            cache, stats = self._chunk_jit(
                self.params, jnp.asarray(piece), self._attn_epoch, cache,
                jnp.asarray(off, I32))
        self._record_stats(stats)
        self.metrics["prefill_tokens"] += clen
        return cache, off + clen

    def _chunk_loop(self, prompt: np.ndarray, cache, off: int):
        """Drive `_chunk_jit` from position `off` to the end of `prompt`."""
        while off < len(prompt):
            cache, off = self._chunk_step(prompt, cache, off)
        return cache

    def _prefill_long(self, req: Request) -> None:
        """Chunked prefill: bucket-sized chunks appended at a pos offset.

        Exactly equivalent to one-shot prefill except for HDP's early head
        gate, which applies per forward call: with tau_h > 0 each chunk
        gates on its own theta_head rather than the whole prompt's (all
        registered configs serve with tau_h = 0, where the paths are
        token-identical — pinned in tests/test_paged_cache.py)."""
        prompt = np.asarray(req.prompt, np.int32)
        t0 = time.perf_counter()
        cache = registry.init_cache(self.cfg, 1, max_len=self.max_len)
        cache = self._chunk_loop(prompt, cache, 0)
        dt = time.perf_counter() - t0
        self.metrics["prefill_s"] += dt
        self.metrics["prefill_calls"] += 1
        self._install(req, cache, 0, dt)

    # ------------------------------------------------- interleaved prefill
    def _begin_stream_prefill(self, req: Request) -> Dict[str, Any]:
        """Open an incremental chunked prefill for the stream scheduler.

        The slot AND the request's full page footprint are reserved up
        front, so a begun prefill can always complete — later pool
        pressure defers *other* admissions, it can never strand a
        half-prefilled prompt. The returned state dict is advanced by
        `_advance_stream_prefill` one token-budget slice per engine
        step, with decode running in between."""
        pages = self._reserve(self._pages_for(req)) if self.paged else []
        slot = self._free.pop(0)
        return {"req": req, "slot": slot, "pages": pages,
                "prompt": np.asarray(req.prompt, np.int32),
                "cache": registry.init_cache(self.cfg, 1,
                                             max_len=self.max_len),
                "off": 0, "spent": 0.0}

    def _advance_stream_prefill(self, st: Dict[str, Any],
                                budget: int) -> bool:
        """Advance an interleaved prefill by >= 1 chunk, up to ``budget``
        prompt tokens; install + activate on completion (returns True).
        The chunk jit and install path are the exact ones `_prefill_long`
        drives in one blocking loop, so the resulting tokens are
        identical — only the pacing differs."""
        prompt = st["prompt"]
        plen = len(prompt)
        t0 = time.perf_counter()
        done = 0
        while st["off"] < plen and done < budget:
            off0 = st["off"]
            st["cache"], st["off"] = self._chunk_step(
                prompt, st["cache"], off0)
            done += st["off"] - off0
            self.metrics["sched_chunk_tokens"] += st["off"] - off0
        st["spent"] += time.perf_counter() - t0
        if st["off"] < plen:
            return False
        self.metrics["prefill_s"] += st["spent"]
        self.metrics["prefill_calls"] += 1
        req, slot = st["req"], st["slot"]
        try:
            if self.paged:
                self.pages.assign(slot, st["pages"])
                st["pages"] = []           # owned by the slot from here
                self.pages.insert(st["cache"], slot, 0)
            else:
                self.slots.insert(st["cache"], slot, 0)
            self._activate(req, slot, st["spent"])
        except BaseException:
            # roll the slot back; _abort_stream_prefill (the scheduler's
            # unwind) returns it and any still-held pages, and requeues
            if self.paged and self.pages.slot_pages(slot):
                self.pages.free(slot)
            self._active.pop(slot, None)
            raise
        st["installed"] = True
        if self.paged and self.prefix is not None:
            self._register_prefix(req, slot)
        return True

    def _abort_stream_prefill(self, st: Dict[str, Any]) -> None:
        """Unwind a failed interleaved prefill: pages and slot return to
        their pools (a prefill that got as far as activation keeps its
        slot — the live request owns the teardown from there)."""
        if st.get("installed"):
            return
        if self.paged and st["pages"]:
            self.pages.allocator.unref(st["pages"])
        self._free.insert(0, st["slot"])

    def _pages_capacity(self) -> int:
        """Pages an admission could obtain right now: the free list plus
        everything LRU eviction could reclaim from the prefix cache —
        the supply side of the scheduler's token-budget check."""
        cap = self.pages.allocator.available
        if self.prefix is not None:
            cap += self.prefix.evictable_pages()
        return cap

    def _prefill_suffix(self, req: Request, shared: List[int],
                        fresh: List[int], slot: int,
                        assigned: List[int]) -> None:
        """Prefix-cache hit: share the matched pages, prefill the suffix.

        The request cache is seeded with the shared pages' K/V (a gather,
        no recompute), the suffix runs through the same chunked-prefill
        jit at offset ``m``, and only suffix/generation pages are fresh —
        the shared span of the insert scatter is scratch-redirected."""
        m = len(shared) * self.pages.page_size
        prompt = np.asarray(req.prompt, np.int32)
        t0 = time.perf_counter()
        cache = self.pages.gather_prefix(shared)
        cache = self._chunk_loop(prompt, cache, m)
        dt = time.perf_counter() - t0
        self.metrics["prefill_s"] += dt
        self.metrics["prefill_calls"] += 1
        self.pages.assign(slot, shared + fresh, first_owned=len(shared))
        assigned.append(slot)              # slot owns every page from here
        self.pages.insert(cache, slot, 0, first_page=len(shared))
        self._activate(req, slot, dt, floor=len(shared))
        self._register_prefix(req, slot)

    def _install_hit(self, req: Request, shared: List[int],
                     fresh: List[int], slot: int,
                     assigned: List[int]) -> None:
        """Full-prompt hit: no prefill at all — every prompt page is
        already resident. The decode resume rewrites the last prompt
        position, which sits in the final shared page: that page is
        copy-on-write duplicated into a slot-owned page first, so the
        shared original stays immutable for its other readers."""
        self.pages.cow(shared[-1], fresh[0])
        self.metrics["cow_copies"] += 1
        pages = shared[:-1] + [fresh[0]] + fresh[1:]
        self.pages.assign(slot, pages, first_owned=len(shared) - 1)
        assigned.append(slot)              # slot owns every page from here
        self.pages.allocator.unref([shared[-1]])   # COW'd out of the slot
        self._activate(req, slot, 0.0, floor=len(shared) - 1)

    def _install(self, req: Request, one_cache, row: int,
                 prefill_s: float) -> None:
        if self.paged:
            pages = self._reserve(self._pages_for(req))  # fallible: first
        slot = self._free.pop(0)
        try:
            if self.paged:
                self.pages.assign(slot, pages)
                self.pages.insert(one_cache, slot, row)
            else:
                self.slots.insert(one_cache, slot, row)
            self._activate(req, slot, prefill_s)
        except BaseException:
            # roll the slot back (requeueing is the caller's job): pages
            # return via the slot if assigned, directly otherwise
            if self.paged:
                if self.pages.slot_pages(slot):
                    self.pages.free(slot)
                else:
                    self.pages.allocator.unref(pages)
            self._active.pop(slot, None)
            self._free.insert(0, slot)
            raise
        if self.paged and self.prefix is not None:
            self._register_prefix(req, slot)

    def _activate(self, req: Request, slot: int, prefill_s: float,
                  floor: int = 0) -> None:
        """Arm a slot's host + device decode state for an installed request.

        Uniform resume: the first decode step replays the last prompt
        token at its own position (its K/V rewrite is idempotent, and
        lands in a slot-owned page — `floor` fences the shared prefix)
        and yields the first generated token — identical for aligned,
        bucket-padded and prefix-shared prompts."""
        plen = len(req.prompt)
        self._active[slot] = {"req": req, "generated": [],
                              "act_seq": self._act_seq}
        self._act_seq += 1
        # prompt_len reports the ORIGINAL submission's prompt (restore
        # resumes fold generated tokens into req.prompt)
        res = Result(req.uid, req.orig_prompt_len or plen, [],
                     prefill_s=prefill_s, preemptions=req.preemptions)
        t_sub = self._t_submit.get(req.uid)
        if t_sub is not None:
            res.queue_wait_s = time.perf_counter() - t_sub
        self._results[req.uid] = res
        self._last_tok = self._last_tok.at[slot, 0].set(int(req.prompt[-1]))
        self._pos = self._pos.at[slot].set(plen - 1)
        self._active_dev = self._active_dev.at[slot].set(True)
        self._remaining_dev = self._remaining_dev.at[slot].set(
            req.max_new_tokens)
        self._eos_dev = self._eos_dev.at[slot].set(
            -1 if req.eos_id is None else req.eos_id)
        self._floor_dev = self._floor_dev.at[slot].set(floor)

    # -------------------------------------------------------------- metrics
    @staticmethod
    def _fresh_metrics() -> Dict[str, float]:
        return {"prefill_s": 0.0, "prefill_calls": 0, "prefill_tokens": 0,
                "decode_s": 0.0, "decode_steps": 0, "tokens_out": 0,
                "block_sparsity": 0.0, "head_sparsity": 0.0,
                "page_sparsity": 0.0, "stat_samples": 0, "page_samples": 0,
                "cow_copies": 0, "spec_rounds": 0, "draft_tokens": 0,
                "accepted_tokens": 0,
                # stream-scheduler counters (zero when it is off)
                "sched_admitted": 0, "sched_recycled": 0,
                "sched_deferred": 0, "sched_chunk_tokens": 0,
                "sched_interleaved_steps": 0, "queue_depth_sum": 0,
                "queue_depth_samples": 0, "queue_depth_peak": 0,
                # fault-tolerance counters
                "sched_preempted": 0, "watchdog_shed": 0,
                "queue_rejected": 0, "faults_injected": 0,
                "req_cancelled": 0, "req_deadline": 0, "req_errors": 0}

    def reset_metrics(self) -> None:
        """Zero the aggregated serving metrics (e.g. after a warmup pass,
        so reported throughput is steady-state rather than compile time)."""
        self.metrics = self._fresh_metrics()

    @staticmethod
    def _masked_mean(x, mask) -> float:
        """Mean over real samples: per-slot decode leaves are [L, B] and
        the active mask drops parked slots; prefill leaves ([L] scalars
        per layer, exact-size stacking — every row real) pass through."""
        x = np.asarray(x)
        if mask is not None and x.ndim >= 2 and x.shape[-1] == len(mask):
            x = x[..., mask]
        return float(np.mean(x))

    def _record_stats(self, stats, mask=None) -> None:
        """Accumulate one AttnStats sample (leaves carry a layer dim).

        ``mask`` [B] bool selects the slots that really decoded this
        step — parked slots run masked inside the fused loop and must
        not dilute the batchwise sparsity means."""
        if not self.collect_stats or stats is None:
            return
        if mask is not None and not mask.any():
            return
        bs = getattr(stats, "block_sparsity", None)
        hs = getattr(stats, "head_sparsity", None)
        if bs is None or hs is None:
            return
        m = self.metrics
        # np.mean works on device and host leaves alike — the fused decode
        # loop hands this numpy slices it already fetched in its one sync
        b_mean = self._masked_mean(bs, mask)
        h_mean = self._masked_mean(hs, mask)
        m["block_sparsity"] += b_mean
        m["head_sparsity"] += h_mean
        if getattr(stats, "page_sparsity", None) is not None:
            # decode-only field: averaged over its own sample count so
            # prefill records don't dilute it
            p_mean = self._masked_mean(stats.page_sparsity, mask)
            m["page_sparsity"] += p_mean
            m["page_samples"] += 1
            if self.tuner is not None:
                # sharpen the cost model's sparse terms with measured
                # decode sparsity (prefill samples carry no page field
                # and would skew the decode-centric EMA)
                self.tuner.observe_sparsity(b_mean, h_mean, p_mean)
        m["stat_samples"] += 1

    def _finish(self, slot: int, now: Optional[float] = None, *,
                status: str = "ok", error: Optional[str] = None) -> None:
        st = self._active.pop(slot)
        req = st["req"]
        res = self._results[req.uid]
        # tokens generated before a preempt/failover restore come first:
        # the restore folded them into the prompt, so the concatenation is
        # byte-identical to an uninterrupted run
        res.tokens = list(req.prior_tokens) + st["generated"]
        res.decode_steps = len(res.tokens)
        res.complete = status == "ok"   # may have been marked incomplete by
        # a prior budget-exhausted run() whose follow-up finished the request
        res.status = status
        res.error = error
        res.preemptions = req.preemptions
        if status != "ok":
            self._count_status(status)
        t_sub = self._t_submit.pop(req.uid, None)
        self._deadlines.pop(req.uid, None)
        t_first = st.get("t_first")
        if t_sub is not None and t_first is not None:
            res.ttft_s = t_first - t_sub
        if now is not None and t_first is not None and len(res.tokens) > 1:
            res.tpot_s = (now - t_first) / (len(res.tokens) - 1)
        self._finished.append(req.uid)
        self._park_slot(slot)

    def _park_slot(self, slot: int) -> None:
        """Release a slot's cache state and return it to the free pool."""
        if self.paged:
            # unref, not free: pages the prefix cache still holds (and
            # pages shared into other live slots) survive the slot
            self.pages.free(slot)
        else:
            self.slots.clear(slot)
        # park the slot on position 0 / token 0: an inactive paged slot's
        # decode writes land in the scratch page via its zeroed table row
        self._pos = self._pos.at[slot].set(0)
        self._last_tok = self._last_tok.at[slot, 0].set(0)
        self._active_dev = self._active_dev.at[slot].set(False)
        self._remaining_dev = self._remaining_dev.at[slot].set(0)
        self._floor_dev = self._floor_dev.at[slot].set(0)
        self._free.append(slot)

    def _count_status(self, status: str) -> None:
        key = {"cancelled": "req_cancelled", "deadline": "req_deadline"} \
            .get(status, "req_errors")
        self.metrics[key] += 1

    # --------------------------------------------------- request lifecycle
    def _fail_request(self, req: Request, *, status: str,
                      error: Optional[str] = None) -> None:
        """Finish a request that never reached (or no longer holds) a
        slot with a typed non-"ok" Result; tokens generated before a
        preempt/failover restore are preserved."""
        res = Result(req.uid, req.orig_prompt_len or len(req.prompt),
                     list(req.prior_tokens), complete=False, status=status,
                     error=error, preemptions=req.preemptions)
        res.decode_steps = len(res.tokens)
        t_sub = self._t_submit.pop(req.uid, None)
        if t_sub is not None:
            res.queue_wait_s = time.perf_counter() - t_sub
        self._deadlines.pop(req.uid, None)
        self._results[req.uid] = res
        self._finished.append(req.uid)
        self._count_status(status)

    def cancel(self, uid: int, *, status: str = "cancelled",
               error: Optional[str] = None) -> bool:
        """Abort a request wherever it currently is — decoding in a slot,
        mid-interleaved-prefill, or queued — unwinding pages/slot/radix
        refs and recording a typed ``Result(status=...)``. Returns True
        when the request was found (False: unknown or already finished).
        """
        for slot, st in list(self._active.items()):
            if st["req"].uid == uid:
                self._finish(slot, time.perf_counter(), status=status,
                             error=error)
                return True
        for req in list(self._queue):
            if req.uid == uid:
                self._queue.remove(req)
                self._fail_request(req, status=status, error=error)
                return True
        if self.sched is not None:
            req = self.sched.cancel(uid)
            if req is not None:
                self._fail_request(req, status=status, error=error)
                return True
        return False

    def _enforce_deadlines(self) -> None:
        """Cancel expired requests (checked once at the top of every
        step — deadline granularity is the engine step, matching the
        one-host-sync-per-horizon design)."""
        if not self._deadlines:
            return
        now = time.perf_counter()
        active_uids = {st["req"].uid for st in self._active.values()}
        for uid, (dl, qdl) in list(self._deadlines.items()):
            if dl is not None and now >= dl:
                self.cancel(uid, status="deadline",
                            error=f"deadline_s exceeded after {now - dl:.3f}s")
            elif qdl is not None and now >= qdl and uid not in active_uids:
                self.cancel(uid, status="deadline",
                            error="max_queue_wait_s exceeded before "
                                  "activation")

    # ---------------------------------------------------- preempt/restore
    @staticmethod
    def _make_resume(req: Request, generated: List[int]) -> Request:
        """Recompute-resume continuation of a running request: generated
        tokens extend the prompt, budget shrinks to match. Greedy decode
        plus the chunked-prefill equivalence make re-serving this request
        byte-identical to never having interrupted it."""
        return dataclasses.replace(
            req,
            prompt=list(req.prompt) + list(generated),
            max_new_tokens=req.max_new_tokens - len(generated),
            prior_tokens=tuple(req.prior_tokens) + tuple(generated),
            orig_prompt_len=req.orig_prompt_len or len(req.prompt),
            preemptions=req.preemptions + 1)

    def _preempt_victim(self, max_priority: int) -> Optional[int]:
        """Slot of the best preemption victim: lowest priority strictly
        below ``max_priority``, newest activation among ties (least sunk
        decode work). None when nothing outranks — equal priorities never
        preempt each other, so the default (all zero) cannot livelock."""
        cands = [(st["req"].priority, -st["act_seq"], slot)
                 for slot, st in self._active.items()
                 if st["req"].priority < max_priority]
        return min(cands)[2] if cands else None

    def _preempt(self, slot: int) -> Request:
        """Tear a running slot down (pages freed, slot recycled, device
        state parked) and return its recompute-resume Request. The
        request's Result shell stays registered — re-activation on
        resume overwrites it."""
        st = self._active.pop(slot)
        resume = self._make_resume(st["req"], st["generated"])
        self._park_slot(slot)
        self.metrics["sched_preempted"] += 1
        return resume

    def _maybe_retune(self) -> None:
        """Flush pending tuner probes (host side, between device steps).

        A measured winner that flips a standing cost decision bumps the
        attention epoch — a static argument of the decode/spec AND
        prefill/chunk jits — so exactly the affected programs re-trace
        once and re-consult the tuner. Called at the top of every step
        and by the stream scheduler when a recycled slot re-enters the
        batch. No-op under static policy."""
        if self.tuner is not None and self.tuner.flush_probes():
            self._attn_epoch += 1

    def step(self) -> int:
        """One engine iteration: admit + one fused decode horizon (or,
        with ``spec_decode``, one fused self-speculative round).

        Generates up to ``horizon`` (``draft_len``) tokens per active
        slot in a single jitted call (one host sync per horizon/round);
        the serving cache is donated to the call, so page-pool updates
        are in place rather than a fresh copy per step. Returns the
        number of active slots stepped.

        With the stream scheduler, admission is one scheduler tick
        instead (budget check, ordering, interleaved prefill advance) and
        the tick's progress feeds the stall watchdog; decode itself
        always progresses (every active slot commits >= 1 token per
        horizon/round), so the watchdog can only trip while the batch is
        empty with requests stuck waiting."""
        try:
            return self._step_inner(self._cur_step)
        finally:
            # one increment per step() call, raise or return — the fault
            # injector keys every hook off this counter, and _reserve
            # reads it mid-step, so it must hold still within a step
            self._cur_step += 1

    def _inject_mask(self, step_no: int):
        """[B] bool mask of slots whose logits this step poisons (the
        NaN-tripwire fault hook); the shared all-False array on the fast
        path so the jit sees one constant donor-safe operand."""
        if self.faults is None:
            return self._zero_inject
        by_uid = {st["req"].uid: slot for slot, st in self._active.items()}
        uids = self.faults.nan_uids(step_no, by_uid)
        if not uids:
            return self._zero_inject
        mask = np.zeros(self.max_batch, bool)
        for u in uids:
            mask[by_uid[u]] = True
        self.metrics["faults_injected"] += len(uids)
        return jnp.asarray(mask)

    def _step_inner(self, step_no: int) -> int:
        if self.faults is not None:
            self.faults.sleep(step_no)
        self._enforce_deadlines()
        self._maybe_retune()
        if self.sched is not None:
            ticked = self.sched.tick()
            self._sample_queue_depth()
        else:
            self._admit()
        if not self._active:
            if self.sched is not None:
                self.sched.watchdog(ticked)
            return 0
        n_stepped = len(self._active)
        if self.spec:
            return self._spec_step(n_stepped, step_no)
        # never scan past the longest remaining budget: the tail of the
        # horizon would provably have no active slot (EOS can still empty
        # a horizon early — those steps run masked and are not recorded)
        rem_max = max(st["req"].max_new_tokens - len(st["generated"])
                      for st in self._active.values())
        length = min(self.horizon, rem_max)

        inject = self._inject_mask(step_no)
        t0 = time.perf_counter()
        store = self.pages if self.paged else self.slots
        cache = store.take()                       # donated to the jit below
        try:
            if self.faults is not None:
                # the harshest crash point: the donated handle is already
                # taken, so the unwind below must restore it or the engine
                # dies of DonatedCacheError on the next step
                self.faults.step_error(step_no)
            if self.paged:
                with self._mesh_ctx():
                    ys, tok, new_cache, pos, active, remaining = \
                        self._decode_jit(
                            length, self._attn_epoch, self.params,
                            self._last_tok, cache, self.pages.table(),
                            self._floor_dev, self._pos, self._active_dev,
                            self._remaining_dev, self._eos_dev, inject)
            else:
                ys, tok, new_cache, pos, active, remaining = self._decode_jit(
                    length, self._attn_epoch, self.params, self._last_tok,
                    cache, self._pos, self._active_dev, self._remaining_dev,
                    self._eos_dev, inject)
        except BaseException:
            # trace/compile failures leave the donated input untouched —
            # restore the handle so the engine stays usable and the real
            # error surfaces instead of a later DonatedCacheError
            store.restore_if_undonated(cache)
            raise
        store.put(new_cache)
        toks_t, act_t, fault_t, stats_t = ys
        # the single host sync of the horizon: tokens, active masks and
        # the (tiny) per-step stats leaves come down in one device_get,
        # and the decode clock stops after it so the stats transfer is
        # billed to decode_s exactly like the per-token path did
        toks_np, act_np, fault_np, stats_np = jax.device_get(
            (toks_t, act_t, fault_t, stats_t))
        t_sync = time.perf_counter()
        self.metrics["decode_s"] += t_sync - t0
        any_act = act_np.any(axis=1)
        ran = int(any_act.sum())                   # steps with any active slot
        self.metrics["decode_steps"] += ran
        self._last_tok = tok
        self._pos = pos
        self._active_dev = active
        self._remaining_dev = remaining
        if self.collect_stats and stats_np is not None:
            for t in range(ran):
                self._record_stats(jax.tree.map(lambda x: x[t], stats_np),
                                   mask=act_np[t])

        for t in range(length):
            if not any_act[t]:
                break
            for slot in list(self._active):
                if not act_np[t, slot]:
                    continue
                if fault_np[t, slot]:
                    # tripwire: this slot's logits went non-finite — its
                    # emitted token is garbage; abort just this request
                    self._finish(slot, t_sync, status="error",
                                 error="non-finite logits (per-slot "
                                       "NaN/poison tripwire)")
                    continue
                st = self._active[slot]
                req = st["req"]
                tokn = int(toks_np[t, slot])
                if not st["generated"]:
                    st["t_first"] = t_sync     # TTFT at sync granularity
                st["generated"].append(tokn)
                self.metrics["tokens_out"] += 1
                done = (len(st["generated"]) >= req.max_new_tokens
                        or (req.eos_id is not None and tokn == req.eos_id))
                if done:
                    self._finish(slot, t_sync)
        if self.sched is not None:
            self.sched.watchdog(True)      # decode progressed
        return n_stepped

    def _spec_step(self, n_stepped: int, step_no: int) -> int:
        """One fused speculative round: draft, verify, accept, rollback.

        Mirrors the horizon step's host side exactly — one device
        dispatch, one host sync, same drain loop — but the emitted mask
        is *commits* (accepted-and-exact tokens) rather than pre-step
        active flags. Commits are prefix runs per slot, so the drain can
        stop at the first all-parked step just like the horizon loop."""
        # never draft past the longest remaining budget: those proposals
        # could not be committed by ANY slot (the same clamp the horizon
        # loop applies to its scan length; at most draft_len distinct
        # compile entries exist per engine)
        rem_max = max(st["req"].max_new_tokens - len(st["generated"])
                      for st in self._active.values())
        if self.spec_ctl is not None:
            k_plan, profile = self.spec_ctl.plan()
            k = min(k_plan, rem_max)
        else:
            k, profile = min(self.draft_len, rem_max), self.draft_profile
        inject = self._inject_mask(step_no)
        t0 = time.perf_counter()
        store = self.pages if self.paged else self.slots
        cache = store.take()                       # donated to the jit below
        try:
            if self.faults is not None:
                self.faults.step_error(step_no)
            if self.paged:
                with self._mesh_ctx():
                    ys, tok, new_cache, pos, active, remaining = \
                        self._spec_jit(
                            k, profile, self._attn_epoch, self.params,
                            self._last_tok, cache, self.pages.table(),
                            self._floor_dev, self._pos, self._active_dev,
                            self._remaining_dev, self._eos_dev, inject)
            else:
                ys, tok, new_cache, pos, active, remaining = self._spec_jit(
                    k, profile, self._attn_epoch, self.params,
                    self._last_tok, cache, self._pos, self._active_dev,
                    self._remaining_dev, self._eos_dev, inject)
        except BaseException:
            store.restore_if_undonated(cache)
            raise
        store.put(new_cache)
        toks_t, com_t, fault_t, stats_t = ys
        toks_np, com_np, fault_np, stats_np = jax.device_get(
            (toks_t, com_t, fault_t, stats_t))
        t_sync = time.perf_counter()
        self.metrics["decode_s"] += t_sync - t0
        n_act = len(self._active)
        n_fault = int(fault_np.sum())
        self.metrics["spec_rounds"] += 1
        self.metrics["draft_tokens"] += (k - 1) * n_act
        # every non-faulted active slot commits >= 1 exact token per
        # round; commits beyond that first one are accepted draft
        # proposals. Parked slots ran masked and commit nothing, and a
        # faulted slot's commits are zeroed by the verify tripwire — they
        # never dilute the acceptance accounting.
        accepted = int(com_np.sum()) - (n_act - n_fault)
        self.metrics["accepted_tokens"] += accepted
        self.metrics["decode_steps"] += int(com_np.any(axis=1).sum())
        if self.spec_ctl is not None:
            self.spec_ctl.update(accepted, (k - 1) * n_act)
        self._last_tok = tok
        self._pos = pos
        self._active_dev = active
        self._remaining_dev = remaining
        if self.collect_stats and stats_np is not None:
            # one verify sample per round, masked to the slots that
            # actually decoded (com_np.any(0) == the pre-round active set)
            self._record_stats(stats_np, mask=com_np.any(axis=0))
        for t in range(k):
            if not com_np[t].any():
                break
            for slot in list(self._active):
                if not com_np[t, slot]:
                    continue
                st = self._active[slot]
                req = st["req"]
                tokn = int(toks_np[t, slot])
                if not st["generated"]:
                    st["t_first"] = t_sync     # TTFT at sync granularity
                st["generated"].append(tokn)
                self.metrics["tokens_out"] += 1
                done = (len(st["generated"]) >= req.max_new_tokens
                        or (req.eos_id is not None and tokn == req.eos_id))
                if done:
                    self._finish(slot, t_sync)
        # faulted rows committed nothing this round (the tripwire fires at
        # verify, before any accept) — abort them after the commit drain
        for slot in list(self._active):
            if fault_np[slot]:
                self._finish(slot, t_sync, status="error",
                             error="non-finite logits (per-slot NaN/poison "
                                   "tripwire)")
        if self.sched is not None:
            self.sched.watchdog(True)      # decode progressed
        return n_stepped

    def _n_pending(self) -> int:
        """Requests not yet finished: active slots, the static queue, and
        (with the stream scheduler) its waiting + mid-prefill set."""
        n = len(self._queue) + len(self._active)
        if self.sched is not None:
            n += self.sched.depth
        return n

    def _pending_requests(self) -> List[Request]:
        reqs = list(self._queue)
        if self.sched is not None:
            reqs += self.sched.pending_requests()
        return reqs

    def _sample_queue_depth(self) -> None:
        """One per-step queue-depth sample (post-tick, so it reads the
        depth the step actually decodes under)."""
        d = self.sched.depth
        m = self.metrics
        m["queue_depth_sum"] += d
        m["queue_depth_samples"] += 1
        if d > m["queue_depth_peak"]:
            m["queue_depth_peak"] = d

    def run(self, max_steps: int = 10_000, *,
            strict: bool = False) -> Dict[int, Result]:
        """Drive until every submitted request completes.

        ``max_steps`` bounds engine iterations (decode horizons, not
        tokens). If the budget runs out with requests unfinished the
        affected Results are marked ``complete=False`` — active slots
        keep their partial tokens, queued requests get an empty Result —
        and a RuntimeWarning is emitted (or RuntimeError when
        ``strict=True``; engine state is left intact either way, so a
        further ``run()`` call can continue).
        """
        steps = 0
        while self._n_pending() and steps < max_steps:
            self.step()
            steps += 1
        if self._n_pending():
            waiting = self._pending_requests()
            msg = (f"Engine.run: step budget {max_steps} exhausted with "
                   f"{len(self._active)} active and {len(waiting)} "
                   f"queued request(s) unfinished")
            for st in self._active.values():
                res = self._results[st["req"].uid]
                res.tokens = list(st["generated"])
                res.decode_steps = len(res.tokens)
                res.complete = False
            for req in waiting:
                self._results[req.uid] = Result(
                    req.uid, len(req.prompt), [], complete=False)
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return dict(self._results)

    def serve(self, reqs: Optional[Sequence[Request]] = None, *,
              max_steps: int = 10_000):
        """Streaming serve loop: yields each Result as it completes.

        ``reqs`` are submitted up front (on top of anything already
        submitted); more requests may be submitted between yields — the
        loop keeps stepping until nothing is pending. Completion order
        is service order, not submission order, whenever the scheduler
        reorders admission or budgets differ. Raises RuntimeError when
        ``max_steps`` engine iterations pass without draining (the
        scheduler's watchdog usually fires first, naming the stuck
        requests)."""
        if reqs is not None:
            for r in reqs:
                self.submit(r)
        emitted = len(self._finished)   # don't re-yield pre-loop results
        steps = 0
        while self._n_pending():
            if steps >= max_steps:
                raise RuntimeError(
                    f"Engine.serve: step budget {max_steps} exhausted "
                    f"with {self._n_pending()} request(s) unfinished")
            self.step()
            steps += 1
            while emitted < len(self._finished):
                uid = self._finished[emitted]
                emitted += 1
                yield self._results[uid]

    def results(self) -> Dict[int, Result]:
        """Snapshot of every Result recorded so far (finished requests
        plus the still-active ones' shells)."""
        return dict(self._results)

    def resolved_backend(self, phase: str) -> str:
        """Name of the backend the registry resolves for a serving phase.

        ``phase``: "prefill" | "decode" | "draft" | "verify" (the last
        two are the speculative round's passes). Uses the SAME call
        constructor as ``attn_apply`` (models.attention.build_attn_call),
        so the report cannot drift from the dispatch. Under the cost
        policy the tuner's recorded decision for the phase (ground truth
        of what a trace actually dispatched) takes precedence; before
        any trace the static resolution is reported. Families without
        attention layers (recurrent) report "none".
        """
        if self.cfg.family in ("rwkv6",):
            return "none"
        decode_like = phase in ("decode", "draft", "verify")
        call = build_attn_call(
            self.cfg, mode="decode" if decode_like else "prefill",
            paged=self.paged and decode_like,
            per_slot=decode_like,
            collect_stats=self.collect_stats,
            draft=self.draft_profile if phase == "draft" else None,
            verify=phase == "verify")
        if self.tuner is not None:
            dec = self.tuner.decision_for(call)
            if dec is not None:
                return dec
        return resolve_backend(call, self.attn_spec).name

    # ------------------------------------------------------------- reporting
    def summary(self) -> Dict[str, float]:
        m = dict(self.metrics)
        if m["decode_s"] > 0:
            m["decode_tok_s"] = m["tokens_out"] / m["decode_s"]
        if m["stat_samples"]:
            m["block_sparsity"] /= m["stat_samples"]
            m["head_sparsity"] /= m["stat_samples"]
        if m["page_samples"]:
            m["page_sparsity"] /= m["page_samples"]
        m["stream_sched"] = self.sched is not None
        if m.pop("queue_depth_samples") and self.sched is not None:
            m["queue_depth_mean"] = (m.pop("queue_depth_sum")
                                     / self.metrics["queue_depth_samples"])
        else:
            m.pop("queue_depth_sum", None)
        ttfts = sorted(r.ttft_s for r in self._results.values()
                       if r.ttft_s is not None)
        if ttfts:
            m["ttft_s_mean"] = float(np.mean(ttfts))
            m["ttft_s_p95"] = float(ttfts[int(0.95 * (len(ttfts) - 1))])
        tpots = [r.tpot_s for r in self._results.values()
                 if r.tpot_s is not None]
        if tpots:
            m["tpot_s_mean"] = float(np.mean(tpots))
        waits = [r.queue_wait_s for r in self._results.values()
                 if r.queue_wait_s is not None]
        if waits:
            m["queue_wait_s_mean"] = float(np.mean(waits))
        m["cache_backend"] = "paged" if self.paged else "dense"
        m["attn_backend_prefill"] = self.resolved_backend("prefill")
        m["attn_backend_decode"] = self.resolved_backend("decode")
        m["attn_policy"] = self.policy
        if m["decode_steps"]:
            m["meas_decode_step_s"] = m["decode_s"] / m["decode_steps"]
        if self.tuner is not None:
            ts = self.tuner.stats()
            m["tuner_hits"] = ts["hits"]
            m["tuner_misses"] = ts["misses"]
            m["tuner_probes"] = ts["probes"]
            m["tuner_cached"] = ts["measured"]
            est = None
            if self.cfg.family not in ("rwkv6",):
                # under spec decode the per-round hot path is the
                # multi-query verify call, not a plain decode step —
                # predict the phase that actually ran
                call = build_attn_call(
                    self.cfg, mode="decode", paged=self.paged,
                    per_slot=True, collect_stats=self.collect_stats,
                    verify=self.spec)
                est = self.tuner.estimate_for(call)
            if est is not None:
                from repro.autotune import predict_engine_step
                _, ce = est
                m["pred_decode_step_s"] = predict_engine_step(
                    registry.param_count(self.cfg, active_only=True),
                    self.max_batch, self.cfg.n_layers, ce, self.tuner.hw)
        if self.faults is not None:
            m["fault_plan"] = self.faults.plan.spec
            m["faults_fired"] = len(self.faults.fired)
        m["spec_decode"] = self.spec
        if self.spec:
            m["draft_len"] = self.draft_len
            m["acceptance_rate"] = (
                m["accepted_tokens"] / m["draft_tokens"]
                if m["draft_tokens"] else 0.0)
            m["attn_backend_draft"] = self.resolved_backend("draft")
            m["attn_backend_verify"] = self.resolved_backend("verify")
            m["adaptive_spec"] = self.spec_ctl is not None
            if self.spec_ctl is not None:
                sc = self.spec_ctl.summary()
                m["acceptance_ema"] = sc["acceptance_ema"]
                m["draft_len_mean"] = sc["draft_len_mean"]
                m["spec_plans"] = sc["rounds"]
        if self.paged:
            # resident bytes at the allocation high-water mark — what a
            # demand-sized pool must hold (the pool itself is max-sized
            # here for static shapes). With the prefix cache on, the peak
            # counts shared pages ONCE — the whole point of sharing.
            m["cache_bytes"] = self.pages.active_bytes(self.pages.peak_pages)
            m["cache_bytes_pool"] = self.pages.pool_bytes()
            m["kv_dtype"] = self.kv_dtype
            m["kv_scale"] = self.kv_scale
            m["tp"] = self.tp
            if self.mesh is not None:
                m["mesh_shape"] = dict(self.mesh.shape)
                m["cache_bytes_pool_per_shard"] = \
                    self.pages.pool_bytes_per_shard()
                # per decode step, per layer: each shard all-gathers the
                # other shards' per-head output slices before the
                # o-projection (the only cross-shard traffic)
                m["collective_bytes_per_layer"] = int(
                    self.max_batch * self.cfg.n_heads * self.cfg.hd * 4
                    * (self.tp - 1) / self.tp)
            m["cache_bytes_per_token"] = self.pages.bytes_per_token()
            m["pages_peak"] = self.pages.peak_pages
            m["pages_in_use"] = self.pages.pages_in_use
            m["page_size"] = self.pages.page_size
            m["prefix_cache"] = self.prefix is not None
            if self.prefix is not None:
                m["prefix_hits"] = self.prefix.hits
                m["prefix_misses"] = self.prefix.misses
                m["prefix_hit_tokens"] = self.prefix.hit_tokens
                m["prefix_evictions"] = self.prefix.evictions
                m["pages_cached"] = self.prefix.cached_pages
        else:
            m["cache_bytes"] = kv_cache.cache_bytes(self.slots.cache)
            m["kv_dtype"] = "fp32"
        return m
