"""Data-parallel engine replicas behind one dispatching front-end.

Above tensor parallelism (which shards ONE engine's page pool across a
mesh's "model" axis — see ``distribution.tp``) sits the replica layer:
N complete engines, each with its own page pool, slot state, scheduler
and prefix cache, served through a single submit/step/serve surface.
Replicas share one params tree, so which replica serves a request never
changes its tokens — dispatch is a pure load/locality decision:

* **prefix affinity** first: the replica whose radix prefix cache holds
  the longest cached prefix of the prompt (a read-only ``peek``) wins —
  re-dispatching a shared-prefix request to the replica that already
  holds the pages turns a cold prefill into a hot one;
* **least-loaded** otherwise: the replica with the fewest pending
  requests (active + queued + scheduler backlog), ties broken by
  replica index for determinism.

``serve`` merges the per-replica completion streams by driving every
replica with pending work one step per iteration and yielding Results
in global finish order.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

from repro.serving.engine import Engine, Request, Result


class ReplicaSet:
    """N engines, one front-end. See module docstring for dispatch."""

    def __init__(self, engines: Sequence[Engine]):
        if not engines:
            raise ValueError("ReplicaSet needs at least one engine")
        self.engines: List[Engine] = list(engines)
        self._home: Dict[int, Engine] = {}      # uid -> serving replica
        self._finish_log: List[int] = []        # uids in global finish order
        self._emitted_per_eng = [0] * len(self.engines)

    @classmethod
    def build(cls, cfg, dp: int, *, params=None, rng=None,
              **engine_kw) -> "ReplicaSet":
        """Build ``dp`` replicas sharing ONE params tree.

        The first engine initializes (or adopts) the params; the rest
        reuse the same tree, so every replica is token-identical by
        construction. Per-engine kwargs (tp, attn, spec_decode, ...)
        apply to every replica alike.
        """
        if dp < 1:
            raise ValueError(f"dp must be >= 1, got {dp}")
        first = Engine(cfg, params=params, rng=rng, **engine_kw)
        rest = [Engine(cfg, params=first.params, **engine_kw)
                for _ in range(dp - 1)]
        return cls([first] + rest)

    # -------------------------------------------------------------- dispatch
    def _pick(self, req: Request) -> Engine:
        best, best_hit = None, 0
        for eng in self.engines:
            if eng.prefix is None:
                continue
            hit = eng.prefix.peek(req.prompt, align=eng._page_align)
            if hit > best_hit:
                best, best_hit = eng, hit
        if best is not None:
            return best
        return min(self.engines, key=lambda e: (e._n_pending(),
                                                self.engines.index(e)))

    def submit(self, req: Request) -> Engine:
        """Dispatch ``req`` to a replica (returned for introspection)."""
        eng = self._pick(req)
        self._home[req.uid] = eng
        eng.submit(req)
        return eng

    # ----------------------------------------------------------------- drive
    def _n_pending(self) -> int:
        return sum(e._n_pending() for e in self.engines)

    def _drain_finished(self) -> List[int]:
        """Collect uids finished since the last drain, in finish order
        (per replica; interleaved round-robin across replicas)."""
        fresh: List[int] = []
        for i, eng in enumerate(self.engines):
            while self._emitted_per_eng[i] < len(eng._finished):
                fresh.append(eng._finished[self._emitted_per_eng[i]])
                self._emitted_per_eng[i] += 1
        self._finish_log.extend(fresh)
        return fresh

    def step(self) -> int:
        """One step of every replica with pending work; returns how many
        replicas stepped."""
        ran = 0
        for eng in self.engines:
            if eng._n_pending():
                eng.step()
                ran += 1
        return ran

    def run(self, max_steps: int = 10_000, *,
            strict: bool = False) -> Dict[int, Result]:
        """Drive every replica until all submitted requests complete."""
        steps = 0
        while self._n_pending() and steps < max_steps:
            self.step()
            steps += 1
        self._drain_finished()
        out: Dict[int, Result] = {}
        for eng in self.engines:
            if steps >= max_steps and eng._n_pending():
                out.update(eng.run(max_steps=0, strict=strict))
            else:
                out.update(eng.results())
        return out

    def serve(self, reqs: Optional[Iterable[Request]] = None, *,
              max_steps: int = 10_000):
        """Merged streaming serve loop: yields each Result as it
        completes, across every replica; more requests may be submitted
        between yields."""
        if reqs is not None:
            for r in reqs:
                self.submit(r)
        self._drain_finished()      # don't re-yield pre-loop results
        steps = 0
        while self._n_pending():
            if steps >= max_steps:
                raise RuntimeError(
                    f"ReplicaSet.serve: step budget {max_steps} exhausted "
                    f"with {self._n_pending()} request(s) unfinished")
            self.step()
            steps += 1
            for uid in self._drain_finished():
                yield self._home[uid]._results[uid]

    # ------------------------------------------------------------- reporting
    def results(self) -> Dict[int, Result]:
        out: Dict[int, Result] = {}
        for eng in self.engines:
            out.update(eng.results())
        return out

    def reset_metrics(self) -> None:
        for eng in self.engines:
            eng.reset_metrics()

    def summary(self) -> Dict[str, object]:
        """Merged summary: fleet totals plus the per-replica summaries."""
        subs = [e.summary() for e in self.engines]
        m: Dict[str, object] = {
            "dp": len(self.engines),
            "tp": self.engines[0].tp,
            "tokens_out": sum(s.get("tokens_out", 0) for s in subs),
            "decode_s": sum(s.get("decode_s", 0.0) for s in subs),
            "prefill_s": sum(s.get("prefill_s", 0.0) for s in subs),
            "requests_per_replica": [
                len(e._results) for e in self.engines],
            "replicas": subs,
        }
        if m["decode_s"]:
            m["decode_tok_s"] = m["tokens_out"] / m["decode_s"]
        for key in ("mesh_shape", "cache_bytes_pool_per_shard",
                    "collective_bytes_per_layer", "kv_dtype", "kv_scale"):
            if key in subs[0]:
                m[key] = subs[0][key]
        return m
