"""Data-parallel engine replicas behind one dispatching front-end.

Above tensor parallelism (which shards ONE engine's page pool across a
mesh's "model" axis — see ``distribution.tp``) sits the replica layer:
N complete engines, each with its own page pool, slot state, scheduler
and prefix cache, served through a single submit/step/serve surface.
Replicas share one params tree, so which replica serves a request never
changes its tokens — dispatch is a pure load/locality decision:

* **prefix affinity** first: the replica whose radix prefix cache holds
  the longest cached prefix of the prompt (a read-only ``peek``) wins —
  re-dispatching a shared-prefix request to the replica that already
  holds the pages turns a cold prefill into a hot one;
* **least-loaded** otherwise: the replica with the fewest pending
  requests (active + queued + scheduler backlog), ties broken by
  replica index for determinism.

``serve`` merges the per-replica completion streams by driving every
replica with pending work one step per iteration and yielding Results
in global finish order.

Failover
--------
Each replica carries a health state (``"up"``/``"dead"``). ``step``
health-checks every member: a step that raises a transient error burns
one of ``step_retries`` strikes and is retried next fleet step; a
non-transient error (or exhausted strikes) kills the replica. A dead
replica's in-flight work — active decode slots (rewound to
recompute-resume requests, exactly like scheduler preemption), queued
and mid-prefill requests — is re-dispatched onto the survivors
**exactly once** per request: a request whose second home also dies is
failed with a typed ``Result(status="error")`` rather than bounced
forever. The dead engine's host queues are cleared so the merged
result stream can never resurrect its stale shells; its device state
and allocator are abandoned as-is (the process-level analogue of a
lost host). Because replicas share params and decode is greedy, a
failed-over request's final token stream is byte-identical to an
uninterrupted run — the prefix cache turns the recompute into a hot
prefill when the survivor has seen the prefix.

A ``FaultInjector`` shared across the fleet (``build(faults=...)`` or
``REPRO_FAULT_PLAN``) drives deterministic chaos: ``kill@S:replica=R``
events are consumed here, per-engine events inside the members.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.common.transient import is_transient
from repro.serving.engine import Engine, Request, Result
from repro.serving.faults import coerce_injector


class ReplicaSet:
    """N engines, one front-end. See module docstring for dispatch."""

    def __init__(self, engines: Sequence[Engine], *, faults=None,
                 step_retries: int = 1):
        if not engines:
            raise ValueError("ReplicaSet needs at least one engine")
        self.engines: List[Engine] = list(engines)
        self._home: Dict[int, Engine] = {}      # uid -> serving replica
        self._finish_log: List[int] = []        # uids in global finish order
        self._emitted_per_eng = [0] * len(self.engines)
        # fleet-level fault injection (kill events); defaults to the
        # members' shared injector so one plan drives the whole stack
        self.faults = (coerce_injector(faults, env=False)
                       or self.engines[0].faults)
        self.step_retries = step_retries
        self.health: List[str] = ["up"] * len(self.engines)
        self._strikes = [0] * len(self.engines)
        self._last_step_s = [0.0] * len(self.engines)
        self._failed_over: Set[int] = set()     # uids moved once already
        self.failovers = 0                      # replicas declared dead
        self.requests_failed_over = 0           # requests re-dispatched
        self._step_no = 0

    @classmethod
    def build(cls, cfg, dp: int, *, params=None, rng=None, faults=None,
              step_retries: int = 1, **engine_kw) -> "ReplicaSet":
        """Build ``dp`` replicas sharing ONE params tree.

        The first engine initializes (or adopts) the params; the rest
        reuse the same tree, so every replica is token-identical by
        construction. Per-engine kwargs (tp, attn, spec_decode, ...)
        apply to every replica alike. ``faults`` (a plan/spec/injector;
        env fallback ``REPRO_FAULT_PLAN``) is coerced ONCE and shared by
        the fleet and every member, so each scheduled event fires
        exactly once fleet-wide.
        """
        if dp < 1:
            raise ValueError(f"dp must be >= 1, got {dp}")
        inj = coerce_injector(faults)
        first = Engine(cfg, params=params, rng=rng, faults=inj, **engine_kw)
        rest = [Engine(cfg, params=first.params, faults=inj, **engine_kw)
                for _ in range(dp - 1)]
        return cls([first] + rest, faults=inj, step_retries=step_retries)

    # -------------------------------------------------------------- dispatch
    def _healthy(self) -> List[Engine]:
        return [e for i, e in enumerate(self.engines)
                if self.health[i] == "up"]

    def _pick(self, req: Request) -> Engine:
        alive = self._healthy()
        if not alive:
            raise RuntimeError("ReplicaSet: every replica is dead")
        best, best_hit = None, 0
        for eng in alive:
            if eng.prefix is None:
                continue
            hit = eng.prefix.peek(req.prompt, align=eng._page_align)
            if hit > best_hit:
                best, best_hit = eng, hit
        if best is not None:
            return best
        return min(alive, key=lambda e: (e._n_pending(),
                                         self.engines.index(e)))

    def submit(self, req: Request, **kw) -> Engine:
        """Dispatch ``req`` to a healthy replica (returned for
        introspection); ``deadline_s``/``max_queue_wait_s`` pass through
        to ``Engine.submit``."""
        eng = self._pick(req)
        self._home[req.uid] = eng
        eng.submit(req, **kw)
        return eng

    def cancel(self, uid: int, **kw) -> bool:
        """Cancel ``uid`` on whichever replica is serving it."""
        eng = self._home.get(uid)
        return eng.cancel(uid, **kw) if eng is not None else False

    # --------------------------------------------------------------- health
    def _kill(self, idx: int, reason: str) -> None:
        """Declare replica ``idx`` dead and fail its work over.

        In-flight requests move to survivors exactly once each; a
        request orphaned a second time gets a typed error Result (on the
        corpse's finish stream, which the merged drain still reads).
        The corpse's host queues are then emptied so ``_n_pending`` /
        ``results()`` never see its stale state again; device arrays and
        the page allocator are abandoned un-freed, like a lost host.
        """
        if self.health[idx] != "up":
            return
        self.health[idx] = "dead"
        self.failovers += 1
        eng = self.engines[idx]
        moved: List[Request] = [
            Engine._make_resume(st["req"], st["generated"])
            for _, st in sorted(eng._active.items())]
        moved += eng._pending_requests()
        eng._active.clear()
        eng._queue.clear()
        if eng.sched is not None:
            eng.sched.waiting.clear()
            eng.sched._chunk = None
        for req in moved:
            # drop the corpse's partial bookkeeping for the request so
            # the survivor's Result is the only one left standing
            eng._results.pop(req.uid, None)
            eng._t_submit.pop(req.uid, None)
            eng._deadlines.pop(req.uid, None)
            if req.uid in self._failed_over:
                eng._fail_request(
                    req, status="error",
                    error=f"lost twice: replica {idx} died ({reason}) "
                          "after an earlier failover")
                continue
            self._failed_over.add(req.uid)
            target = self._pick(req)
            self._home[req.uid] = target
            target.submit(req)
            self.requests_failed_over += 1

    # ----------------------------------------------------------------- drive
    def _n_pending(self) -> int:
        return sum(e._n_pending() for e in self._healthy())

    def _drain_finished(self) -> List[int]:
        """Collect uids finished since the last drain, in finish order
        (per replica; interleaved round-robin across replicas)."""
        fresh: List[int] = []
        for i, eng in enumerate(self.engines):
            while self._emitted_per_eng[i] < len(eng._finished):
                fresh.append(eng._finished[self._emitted_per_eng[i]])
                self._emitted_per_eng[i] += 1
        self._finish_log.extend(fresh)
        return fresh

    def step(self) -> int:
        """One step of every healthy replica with pending work; returns
        how many replicas stepped. Fires due replica-kill fault events
        first; a member whose step raises is retried (transient, within
        ``step_retries`` strikes) or killed and failed over."""
        step_no = self._step_no
        self._step_no += 1
        if self.faults is not None:
            for r in self.faults.kills(step_no):
                if 0 <= r < len(self.engines):
                    self._kill(r, f"injected kill at fleet step {step_no}")
        ran = 0
        for i, eng in enumerate(self.engines):
            if self.health[i] != "up" or not eng._n_pending():
                continue
            t0 = time.perf_counter()
            try:
                eng.step()
            except Exception as e:  # noqa: BLE001 - classified below
                if is_transient(e) and self._strikes[i] < self.step_retries:
                    self._strikes[i] += 1
                    continue
                self._kill(i, f"{type(e).__name__}: {e}")
                continue
            self._strikes[i] = 0
            self._last_step_s[i] = time.perf_counter() - t0
            ran += 1
        return ran

    def run(self, max_steps: int = 10_000, *,
            strict: bool = False) -> Dict[int, Result]:
        """Drive every replica until all submitted requests complete."""
        steps = 0
        while self._n_pending() and steps < max_steps:
            self.step()
            steps += 1
        self._drain_finished()
        out: Dict[int, Result] = {}
        for i, eng in enumerate(self.engines):
            if (steps >= max_steps and self.health[i] == "up"
                    and eng._n_pending()):
                out.update(eng.run(max_steps=0, strict=strict))
            else:
                out.update(eng.results())
        return out

    def serve(self, reqs: Optional[Iterable[Request]] = None, *,
              max_steps: int = 10_000):
        """Merged streaming serve loop: yields each Result as it
        completes, across every replica; more requests may be submitted
        between yields."""
        if reqs is not None:
            for r in reqs:
                self.submit(r)
        self._drain_finished()      # don't re-yield pre-loop results
        steps = 0
        while self._n_pending():
            if steps >= max_steps:
                raise RuntimeError(
                    f"ReplicaSet.serve: step budget {max_steps} exhausted "
                    f"with {self._n_pending()} request(s) unfinished")
            self.step()
            steps += 1
            for uid in self._drain_finished():
                yield self._home[uid]._results[uid]

    # ------------------------------------------------------------- reporting
    def results(self) -> Dict[int, Result]:
        out: Dict[int, Result] = {}
        for eng in self.engines:
            out.update(eng.results())
        return out

    def reset_metrics(self) -> None:
        for eng in self.engines:
            eng.reset_metrics()

    def summary(self) -> Dict[str, object]:
        """Merged summary: fleet totals plus the per-replica summaries."""
        subs = [e.summary() for e in self.engines]
        m: Dict[str, object] = {
            "dp": len(self.engines),
            "tp": self.engines[0].tp,
            "tokens_out": sum(s.get("tokens_out", 0) for s in subs),
            "decode_s": sum(s.get("decode_s", 0.0) for s in subs),
            "prefill_s": sum(s.get("prefill_s", 0.0) for s in subs),
            "requests_per_replica": [
                len(e._results) for e in self.engines],
            # per-replica health + load observability (serve CLI output)
            "health": list(self.health),
            "failovers": self.failovers,
            "requests_failed_over": self.requests_failed_over,
            "replica_queue_depth": [e._n_pending() for e in self.engines],
            "replica_inflight": [len(e._active) for e in self.engines],
            "replica_last_step_s": list(self._last_step_s),
            "replicas": subs,
        }
        if m["decode_s"]:
            m["decode_tok_s"] = m["tokens_out"] / m["decode_s"]
        if self.faults is not None:
            m["fault_plan"] = self.faults.plan.spec
            m["faults_fired"] = len(self.faults.fired)
        for key in ("mesh_shape", "cache_bytes_pool_per_shard",
                    "collective_bytes_per_layer", "kv_dtype", "kv_scale"):
            if key in subs[0]:
                m[key] = subs[0][key]
        return m
