from repro.serving.allocator import (PageAllocator, PoolExhausted,  # noqa: F401
                                     RadixPrefixCache)
from repro.serving.engine import Engine, Request, Result  # noqa: F401
from repro.serving.kv_cache import PagedKVCache, SlotCache  # noqa: F401
from repro.serving.replica import ReplicaSet  # noqa: F401
from repro.serving.scheduler import (SchedulerConfig, StreamScheduler,  # noqa: F401
                                     WatchdogError)
