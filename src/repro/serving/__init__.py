from repro.serving.engine import Engine, Request, Result  # noqa: F401
from repro.serving.kv_cache import SlotCache  # noqa: F401
