from repro.serving.allocator import PageAllocator, RadixPrefixCache  # noqa: F401
from repro.serving.engine import Engine, Request, Result  # noqa: F401
from repro.serving.kv_cache import PagedKVCache, SlotCache  # noqa: F401
