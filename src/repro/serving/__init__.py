from repro.common.transient import TransientError, is_transient  # noqa: F401
from repro.serving.allocator import (PageAllocator, PoolExhausted,  # noqa: F401
                                     RadixPrefixCache)
from repro.serving.engine import Engine, Request, Result  # noqa: F401
from repro.serving.faults import (FAULT_ENV, FaultInjector,  # noqa: F401
                                  FaultPlan, InjectedFault)
from repro.serving.kv_cache import PagedKVCache, SlotCache  # noqa: F401
from repro.serving.replica import ReplicaSet  # noqa: F401
from repro.serving.scheduler import (QueueFull, SchedulerConfig,  # noqa: F401
                                     StreamScheduler, WatchdogError)
